"""Token pipeline for the LM architectures (train_4k etc.).

Produces deterministic synthetic token streams with Zipfian unigram
statistics plus short-range bigram structure so that per-step loss actually
decreases during smoke training (a uniform stream would be incompressible);
determinism and statistics are pinned by tests/test_substrate.py::TestData.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        # sticky bigram: with p=0.5 the next token is (prev*7+3) % v
        self._sticky = 0.5

    def next_batch(self) -> tuple[np.ndarray, np.ndarray]:
        b, s, v = self.batch_size, self.seq_len, self.vocab_size
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = self._rng.choice(v, b, p=self._unigram)
        sticky = self._rng.random((b, s)) < self._sticky
        fresh = self._rng.choice(v, (b, s), p=self._unigram)
        for t in range(s):
            nxt = (toks[:, t].astype(np.int64) * 7 + 3) % v
            toks[:, t + 1] = np.where(sticky[:, t], nxt, fresh[:, t])
        return toks[:, :-1], toks[:, 1:]


def synthetic_token_batch(vocab: int, batch: int, seq: int, seed: int = 0
                          ) -> tuple[np.ndarray, np.ndarray]:
    return TokenPipeline(vocab, seq, batch, seed).next_batch()
