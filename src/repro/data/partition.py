"""Heterogeneous data partitioners (statistical non-IID-ness).

The seed partitioners (:func:`repro.data.mnist.partition_iid` and the crude
label-subset :func:`repro.data.mnist.partition_noniid`) are complemented by
the two standard federated-heterogeneity generators:

* :func:`partition_dirichlet` -- label skew: for every class c the class's
  samples are split across the M devices by proportions drawn from
  Dirichlet(alpha * 1_M).  Small alpha concentrates each class on few
  devices (high skew), large alpha approaches IID.
* :func:`partition_quantity_skew` -- quantity skew: device shard *sizes* are
  Dirichlet(alpha)-distributed over a label-balanced shuffle.

Both are exact partitions -- every sample lands on exactly one device, no
sample is lost or duplicated, every device is non-empty -- and fully
deterministic per ``seed`` (``np.random.default_rng``).  These invariants
are pinned by Hypothesis property tests in tests/test_scenarios.py.

Population scale.  100k+ device populations do not materialize 100k shards:
:mod:`repro.core.population` partitions into a fixed pool of ``n_shards``
shards and maps every global device id onto one via
:func:`shard_for_device` (``id % n_shards``).  The mapping is a pure
function of the global id -- no RNG, no mesh-layout dependence -- so a
sampled cohort reads the same data rows under any engine; pinned by the
loop==batched population equivalence in
tests/test_population.py::TestPopulationEquivalence.
"""
from __future__ import annotations

import numpy as np

Shards = list[tuple[np.ndarray, np.ndarray]]


def _rebalance_nonempty(device_idx: list[list[int]]) -> None:
    """Move single samples from the largest shards into empty ones."""
    for dev in range(len(device_idx)):
        while not device_idx[dev]:
            donor = max(range(len(device_idx)),
                        key=lambda j: len(device_idx[j]))
            if len(device_idx[donor]) <= 1:
                raise ValueError("fewer samples than devices")
            device_idx[dev].append(device_idx[donor].pop())


def partition_dirichlet(x: np.ndarray, y: np.ndarray, m: int,
                        alpha: float = 0.5, seed: int = 0) -> Shards:
    """Dirichlet(alpha) label-skew partition (Hsu et al. 2019 style).

    Per class c: p ~ Dir(alpha * 1_M), the shuffled class-c indices are cut
    at the cumulative proportions and dealt to the devices.  alpha -> 0
    gives near-single-class devices; alpha -> inf recovers IID.
    """
    if m < 1:
        raise ValueError(f"need at least one device, got m={m}")
    if alpha <= 0:
        raise ValueError(f"alpha must be positive, got {alpha}")
    rng = np.random.default_rng(seed)
    n_classes = int(y.max()) + 1
    device_idx: list[list[int]] = [[] for _ in range(m)]
    for c in range(n_classes):
        idx_c = np.flatnonzero(y == c)
        rng.shuffle(idx_c)
        p = rng.dirichlet(np.full(m, alpha))
        cuts = (np.cumsum(p)[:-1] * len(idx_c)).astype(int)
        for dev, part in enumerate(np.split(idx_c, cuts)):
            device_idx[dev].extend(part.tolist())
    _rebalance_nonempty(device_idx)
    out = []
    for dev in range(m):
        idx = np.array(sorted(device_idx[dev]), dtype=np.int64)
        out.append((x[idx], y[idx]))
    return out


def partition_quantity_skew(x: np.ndarray, y: np.ndarray, m: int,
                            alpha: float = 0.5, seed: int = 0) -> Shards:
    """Quantity-skew partition: shard sizes ~ Dirichlet(alpha), labels IID.

    Sizes use largest-remainder rounding with a floor of one sample per
    device, over one global shuffle -- so label marginals stay near the
    global distribution while shard sizes get more unequal as alpha -> 0.
    """
    if m < 1:
        raise ValueError(f"need at least one device, got m={m}")
    if alpha <= 0:
        raise ValueError(f"alpha must be positive, got {alpha}")
    n = int(x.shape[0])
    if n < m:
        raise ValueError(f"fewer samples ({n}) than devices ({m})")
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    p = rng.dirichlet(np.full(m, alpha))
    # largest-remainder apportionment of n - m spare samples on top of the
    # one-per-device floor: exact partition, deterministic, all non-empty
    raw = p * (n - m)
    counts = np.floor(raw).astype(np.int64)
    rem = int(n - m - counts.sum())
    if rem:
        order = np.argsort(-(raw - counts), kind="stable")
        counts[order[:rem]] += 1
    counts += 1
    cuts = np.cumsum(counts)[:-1]
    return [(x[np.sort(s)], y[np.sort(s)]) for s in np.split(perm, cuts)]


def shard_for_device(dev_ids: np.ndarray, n_shards: int) -> np.ndarray:
    """Map global device ids onto the population's fixed shard pool.

    ``id % n_shards``: deterministic, id-keyed (shard-layout independent),
    and surjective for any population with N >= n_shards -- every shard in
    the pool backs ~N/n_shards devices.  Devices sharing a shard still draw
    disjoint minibatch streams (TAG_BATCH is keyed per device id)."""
    dev_ids = np.asarray(dev_ids)
    if n_shards < 1:
        raise ValueError(f"need at least one shard, got {n_shards}")
    return dev_ids % n_shards


def label_marginals(shards: Shards, n_classes: int | None = None
                    ) -> np.ndarray:
    """(M, n_classes) per-device label distributions (rows sum to 1)."""
    ys = [y for _, y in shards]
    if n_classes is None:
        n_classes = int(max(int(y.max()) for y in ys if y.size)) + 1
    out = np.zeros((len(shards), n_classes))
    for i, y in enumerate(ys):
        binc = np.bincount(y.astype(np.int64), minlength=n_classes)
        out[i] = binc / max(1, y.size)
    return out


def skew_score(shards: Shards) -> float:
    """Mean total-variation distance between device label marginals and the
    pooled marginal -- 0 for perfectly IID shards, -> 1 for single-class
    devices.  Used to verify the alpha-direction of Dirichlet skew."""
    marg = label_marginals(shards)
    sizes = np.array([y.size for _, y in shards], dtype=np.float64)
    pooled = (marg * sizes[:, None]).sum(0) / sizes.sum()
    return float(0.5 * np.abs(marg - pooled).sum(1).mean())
