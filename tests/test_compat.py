"""Tests for the launch/compat version shims.

``ensure_fast_cpu_runtime`` is the load-bearing PR-7 path: it decides,
from the jaxlib version and the process environment, whether the
``--xla_cpu_use_thunk_runtime=false`` flag is appended to ``XLA_FLAGS``
before backend init (docs/ARCHITECTURE.md §10).  A wrong decision is
either a 37x slowdown (flag missing on 0.4.3x) or a hard startup crash
(unknown flag on >= 0.5), so the version gate's *boundaries* are pinned
here with mocked jaxlib versions -- the function reads
``jaxlib.__version__`` at call time, which is what makes it mockable.
"""
from __future__ import annotations

import jaxlib
import pytest

from repro.launch.compat import ensure_fast_cpu_runtime

FLAG = "--xla_cpu_use_thunk_runtime=false"


@pytest.fixture
def clean_env(monkeypatch):
    """No XLA_FLAGS, no opt-out: the decision rests on the version gate."""
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    monkeypatch.delenv("REPRO_XLA_THUNK_RUNTIME", raising=False)
    return monkeypatch


class TestVersionGate:
    """The flag applies exactly on [0.4.32, 0.5.0) -- the jaxlib line that
    ships both runtimes.  Outside it the flag is unknown to XLA (hard
    startup error), so both boundaries matter."""

    @pytest.mark.parametrize("version,expected", [
        ("0.4.31", False),    # pre-thunk-runtime: nothing to opt out of
        ("0.4.32", True),     # first thunk-runtime release
        ("0.4.37", True),     # the pinned CI container
        ("0.4.38.dev20250101", True),   # dev builds parse by numeric prefix
        ("0.5.0", False),     # legacy runtime removed; flag now fatal
        ("0.6.1", False),
    ])
    def test_boundary(self, clean_env, version, expected):
        clean_env.setattr(jaxlib, "__version__", version)
        import os
        assert ensure_fast_cpu_runtime() is expected
        assert (FLAG in os.environ.get("XLA_FLAGS", "")) is expected

    def test_unparseable_version_is_a_noop(self, clean_env):
        clean_env.setattr(jaxlib, "__version__", "weekly-nightly")
        import os
        assert ensure_fast_cpu_runtime() is False
        assert "XLA_FLAGS" not in os.environ


class TestOptOut:
    def test_env_opt_out_wins_over_version(self, clean_env):
        clean_env.setattr(jaxlib, "__version__", "0.4.37")
        clean_env.setenv("REPRO_XLA_THUNK_RUNTIME", "1")
        import os
        assert ensure_fast_cpu_runtime() is False
        assert "XLA_FLAGS" not in os.environ

    def test_opt_out_only_honours_exactly_1(self, clean_env):
        clean_env.setattr(jaxlib, "__version__", "0.4.37")
        clean_env.setenv("REPRO_XLA_THUNK_RUNTIME", "0")
        assert ensure_fast_cpu_runtime() is True


class TestIdempotence:
    def test_second_call_does_not_duplicate_the_flag(self, clean_env):
        clean_env.setattr(jaxlib, "__version__", "0.4.35")
        import os
        assert ensure_fast_cpu_runtime() is True
        flags_after_first = os.environ["XLA_FLAGS"]
        assert ensure_fast_cpu_runtime() is True
        assert os.environ["XLA_FLAGS"] == flags_after_first
        assert flags_after_first.count(FLAG) == 1

    def test_flag_already_present_short_circuits_any_version(self, clean_env):
        # a caller (or CI lane) that already set the flag wins outright,
        # even on a jaxlib where the gate itself would say no
        clean_env.setattr(jaxlib, "__version__", "0.5.0")
        clean_env.setenv("XLA_FLAGS", f"--some_other_flag {FLAG}")
        import os
        before = os.environ["XLA_FLAGS"]
        assert ensure_fast_cpu_runtime() is True
        assert os.environ["XLA_FLAGS"] == before

    def test_existing_xla_flags_content_is_preserved(self, clean_env):
        clean_env.setattr(jaxlib, "__version__", "0.4.33")
        clean_env.setenv(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
        import os
        assert ensure_fast_cpu_runtime() is True
        flags = os.environ["XLA_FLAGS"].split()
        assert "--xla_force_host_platform_device_count=8" in flags
        assert FLAG in flags
