"""Population-scale cohort sampling: N >= 100k devices, M-device windows.

LGC's premise is an edge network of *millions* of devices, but the engines in
:mod:`repro.core.fl` / :mod:`repro.core.fl_batched` run full participation
with M in the tens.  This module adds the population layer of the engine
ladder: a :class:`Population` holds host-resident per-device state for all N
devices -- error-feedback residuals behind a pluggable store
(:data:`repro.core.error_feedback.EF_STORES`: "dense" | "int8" | "server"),
scenario chain carries, resource spend -- and :func:`run_population` draws a
cohort of M devices per sync window, gathers their state into the (M, .)
stacked pytrees the batched window body already consumes, runs the unchanged
:func:`repro.core.fl_batched.make_device_phase`, and scatters the updated
state back.

Cohort contract (docs/ARCHITECTURE.md §8).  Windows are synchronous: every
window spans ``h`` rounds, the whole cohort syncs at its end, and the next
window re-draws.  The cohort is drawn by :func:`sample_cohort` from the
counter-based TAG_COHORT stream keyed by the window's *start round* only --
never by device position or mesh layout -- so the draw is deterministic per
(seed, round) and identical under any engine/mesh
(tests/test_population.py::TestCohortSampling).  Samplers are registry
entries (:data:`COHORT_SAMPLERS`): "uniform", and Jung-et-al.-2024-style
"weighted" biased selection where zero-weight devices are never drawn.
Cohort members start each window from the freshly broadcast global model;
their scenario chains advance only during rounds they participate in
("participation time"), keyed by (global round, global device id) like every
other stream.

Equivalence.  All population engines ("loop" | "batched" | "sharded") run
the SAME compiled device phase -- at block sizes 1, M and M/D respectively
-- and feed the assembled (M, D) update matrix through one shared jitted
server step, so the sampled-cohort ladder holds *bitwise* for the dense EF
store and allclose within pinned tolerance for the int8 store
(tests/test_population.py::TestPopulationEquivalence; the bitwise half
rests on the batch-shape stability of per-row float math on XLA:CPU,
docs/ARCHITECTURE.md §4).

Data at population scale is a fixed pool of shards: global device id i
reads shard ``i % n_shards`` (:func:`repro.data.partition.shard_for_device`)
while drawing its own TAG_BATCH minibatch stream, so no N-sized data
structure ever materializes.  :func:`make_population_task` builds a
self-contained synthetic classification task small enough that a dense
100k-device EF store fits in tens of MB (benchmarks/bench_population.py
measures all three stores into BENCH_population.json).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .channels import DEFAULT_CHANNELS, comp_cost
from .compressor import flatten_tree, tree_size, unflatten_like
from .error_feedback import EF_STORES, make_ef_store
from .fl import (FLConfig, FLTask, History, TAG_COHORT, TAG_EVAL,
                 get_scenario, stream_key)
from .fl_batched import _stack_device_data, make_device_phase
from .scenario import Scenario, ScenarioCarry, init_carry
from .server import (diloco_update, get_aggregator, init_server_state,
                     semi_sync_sums, semi_sync_update, staleness_schedule,
                     window_deadline)

Array = jax.Array


# ---------------------------------------------------------------------------
# cohort samplers (registry; counter-based TAG_COHORT stream)
# ---------------------------------------------------------------------------

def _sample_uniform(key: Array, n: int, m: int,
                    weights: np.ndarray | None) -> Array:
    return jax.random.choice(key, n, (m,), replace=False)


def _sample_weighted(key: Array, n: int, m: int,
                     weights: np.ndarray | None) -> Array:
    if weights is None:
        raise ValueError("'weighted' sampler needs per-device weights")
    p = jnp.asarray(weights, jnp.float32)
    return jax.random.choice(key, n, (m,), replace=False, p=p / jnp.sum(p))


COHORT_SAMPLERS: dict[str, Callable] = {
    # every device equally likely (classic FedAvg client sampling)
    "uniform": _sample_uniform,
    # biased/resource-aware selection (Jung et al. 2024): draw proportional
    # to non-negative per-device weights; zero-weight devices never appear
    "weighted": _sample_weighted,
}


def sample_cohort(base: Array, sampler: str, n: int, m: int, t: int,
                  weights: np.ndarray | None = None) -> np.ndarray:
    """Draw the M-device cohort for the window starting at round ``t``.

    Keyed by ``stream_key(base, TAG_COHORT, t)`` alone -- a pure function of
    (seed, round), independent of engine blocking and mesh layout -- and
    without replacement, so ids are unique and scatters conflict-free.
    Returns global device ids as an (M,) int64 numpy array, in draw order
    (all engines consume the same order, which fixes the server reduce
    order)."""
    if not 0 < m <= n:
        raise ValueError(f"cohort size {m} not in 1..{n}")
    try:
        fn = COHORT_SAMPLERS[sampler]
    except KeyError:
        raise ValueError(f"unknown cohort sampler {sampler!r}; registered: "
                         f"{sorted(COHORT_SAMPLERS)}") from None
    if weights is not None:
        w = np.asarray(weights, np.float64)
        if w.shape != (n,):
            raise ValueError(f"weights shape {w.shape} != ({n},)")
        if (w < 0).any() or w.sum() <= 0:
            raise ValueError("weights must be non-negative with positive sum")
        if sampler == "weighted" and m > int((w > 0).sum()):
            raise ValueError(f"cohort size {m} exceeds the "
                             f"{int((w > 0).sum())} positive-weight devices")
    ids = fn(stream_key(base, TAG_COHORT, t), n, m, weights)
    return np.asarray(ids, np.int64)


# ---------------------------------------------------------------------------
# the population: host-resident per-device state for all N devices
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Population:
    """All-N device state; windows gather/scatter M-device cohorts of it.

    Built by :func:`make_population`.  Per-device state lives on the host:
    the EF residual store (``ef_store``, one of :data:`EF_STORES`), the
    scenario chain carries, f64 resource spend and participation counts.
    ``task.device_data`` is the fixed shard pool -- device i reads shard
    ``i % n_shards``."""
    task: FLTask
    n: int
    scenario: Scenario
    ef_store: object
    sampler: str
    weights: np.ndarray | None
    seed: int
    d: int
    # host state pools, all indexed by global device id
    carry_bw: np.ndarray        # (N, C) f32 AR(1) log-bandwidth deviation
    carry_good: np.ndarray      # (N, C) bool Gilbert-Elliott state
    spend: np.ndarray           # (N, 4) f64: energy_j, money, time_s, mb
    participation: np.ndarray   # (N,) int64 windows participated

    @property
    def n_shards(self) -> int:
        return len(self.task.device_data)

    @property
    def ef_nbytes(self) -> int:
        """Exact EF-state footprint (stores allocate upfront, so this is
        also the peak)."""
        return self.ef_store.nbytes


def make_population(task: FLTask, n_devices: int, ef_store: str = "dense",
                    sampler: str = "uniform",
                    weights: np.ndarray | None = None,
                    scenario: str | Scenario | None = None,
                    seed: int = 0,
                    n_channels: int = len(DEFAULT_CHANNELS)) -> Population:
    """Build an N-device :class:`Population` over ``task``'s shard pool.

    ``ef_store``: "dense" (lossless, 4*N*D bytes), "int8" (N*(D+4) bytes,
    quantized residuals) or "server" (4*D bytes, one aggregate residual) --
    see :data:`repro.core.error_feedback.EF_STORES`.  ``sampler`` /
    ``weights`` configure :func:`sample_cohort`.  The scenario chain carries
    of all N devices are stationary-initialized from the same TAG_SCEN_INIT
    stream the full-participation engines use, keyed by global device id.
    """
    if n_devices < len(task.device_data):
        raise ValueError(f"population of {n_devices} smaller than the "
                         f"{len(task.device_data)}-shard data pool")
    if ef_store not in EF_STORES:
        raise ValueError(f"unknown EF store {ef_store!r}; registered: "
                         f"{sorted(EF_STORES)}")
    if sampler not in COHORT_SAMPLERS:
        raise ValueError(f"unknown cohort sampler {sampler!r}; registered: "
                         f"{sorted(COHORT_SAMPLERS)}")
    scn = get_scenario(scenario)
    d = tree_size(task.init(jax.random.PRNGKey(seed)))
    base = jax.random.PRNGKey(seed + 1)
    ids = jnp.arange(n_devices, dtype=jnp.int32)
    carry = jax.vmap(lambda i: init_carry(scn, base, i, n_channels))(ids)
    if weights is not None:
        weights = np.asarray(weights, np.float64)
        if weights.shape != (n_devices,):
            raise ValueError(
                f"weights shape {weights.shape} != ({n_devices},)")
    return Population(
        task=task, n=n_devices, scenario=scn,
        ef_store=make_ef_store(ef_store, n_devices, d),
        sampler=sampler, weights=weights, seed=seed, d=d,
        carry_bw=np.array(carry.bw_log),     # np.array: writable host copies
        carry_good=np.array(carry.good),
        spend=np.zeros((n_devices, 4), np.float64),
        participation=np.zeros((n_devices,), np.int64))


# ---------------------------------------------------------------------------
# a population-sized task: tiny synthetic classification over a shard pool
# ---------------------------------------------------------------------------

def make_population_task(n_shards: int = 8, n_train: int = 4096,
                         n_eval: int = 1024, n_features: int = 16,
                         n_classes: int = 4, seed: int = 0,
                         partition: str = "iid",
                         alpha: float = 0.5) -> FLTask:
    """Synthetic Gaussian-blob logistic regression sized for N >= 100k.

    D = (n_features + 1) * n_classes = 68 at the defaults, so a dense
    100k-device EF store is ~27 MB (vs ~3 GB at MNIST-LR size) and the int8
    store lands at (D + 4) / (4 D) ~ 26% of dense.  Data is partitioned into
    ``n_shards`` pool shards (``partition``: "iid" | "noniid" | "dirichlet"
    | "quantity") that the population maps device ids onto via
    :func:`repro.data.partition.shard_for_device`."""
    from .scenario import partition_fn
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_classes, n_features)) * 3.0
    y = rng.integers(0, n_classes, size=n_train + n_eval)
    x = centers[y] + rng.normal(size=(y.size, n_features))
    x = x.astype(np.float32)
    y = y.astype(np.int32)
    xt, yt = x[:n_train], y[:n_train]
    xe, ye = x[n_train:], y[n_train:]
    scn = Scenario(name="population_task", partition=partition, alpha=alpha)
    shards = partition_fn(scn)(xt, yt, n_shards, seed)

    def init(key):
        k1, _ = jax.random.split(key)
        return {"w": jax.random.normal(k1, (n_features, n_classes)) * 0.01,
                "b": jnp.zeros((n_classes,))}

    def logits(params, xb):
        return xb @ params["w"] + params["b"]

    def loss_fn(params, batch):
        xb, yb = batch
        logp = jax.nn.log_softmax(logits(params, xb), -1)
        return -jnp.mean(jnp.take_along_axis(logp, yb[..., None], -1))

    def metric_fn(params, batch):
        xb, yb = batch
        pred = jnp.argmax(logits(params, xb), -1)
        return jnp.mean((pred == yb).astype(jnp.float32))

    return FLTask(init=init, loss_fn=loss_fn, metric_fn=metric_fn,
                  device_data=shards, eval_data=(xe, ye),
                  name=f"population_blobs_{n_features}x{n_classes}")


# ---------------------------------------------------------------------------
# the cohort window loop
# ---------------------------------------------------------------------------

def _pad_pow2(t: int, te: int, eta_fn) -> tuple[Array, Array, Array]:
    """(ts, etas, valid) for rounds [t, te), padded to a power of two so few
    scan programs compile -- same rule as BatchedEngine.run."""
    length = te - t
    pad = (1 << (length - 1).bit_length()) - length
    ts = jnp.asarray(list(range(t, te)) + [te - 1] * pad, jnp.int32)
    etas = jnp.asarray([eta_fn(tt) for tt in range(t, te)] + [0.0] * pad,
                       jnp.float32)
    valid = jnp.asarray([True] * length + [False] * pad)
    return ts, etas, valid


def run_population(pop: Population, cfg: FLConfig, mode: str = "lgc",
                   h: int = 4, ks: Sequence[int] | None = None,
                   m_cohort: int = 64, engine: str = "batched",
                   backend: str | None = None, mesh=None) -> History:
    """Run sampled-cohort LGC over ``pop`` and return a :class:`History`.

    Every window: draw M = ``m_cohort`` devices (:func:`sample_cohort`),
    gather their EF residuals / scenario carries / data shards into (M, .)
    stacks, broadcast the global model, run ``h`` local rounds plus the sync
    through the shared :func:`~repro.core.fl_batched.make_device_phase`,
    apply the cohort-mean server update, scatter state back.

    ``engine`` picks the blocking of the SAME device-phase program: "batched"
    (one (M, .) block), "loop" (M single-row blocks -- the reference), or
    "sharded" ((M/D, .) mesh-local blocks under shard_map).  All three
    produce bit-identical History with the dense EF store (the sampled-cohort
    equivalence contract, tests/test_population.py)."""
    if engine not in ("batched", "loop", "sharded"):
        raise ValueError(f"unknown population engine {engine!r}")
    if cfg.seed != pop.seed:
        raise ValueError(f"cfg.seed={cfg.seed} but the population was built "
                         f"with seed={pop.seed}; streams would diverge")
    cfg_scn = get_scenario(cfg.scenario)
    if cfg_scn.name not in ("static", pop.scenario.name):
        raise ValueError(
            f"cfg.scenario={cfg_scn.name!r} conflicts with the population's "
            f"{pop.scenario.name!r}; pass the scenario to make_population")
    task, scn = pop.task, pop.scenario
    backend = backend or cfg.backend
    params = task.init(jax.random.PRNGKey(cfg.seed))
    d = pop.d
    n_ch = len(cfg.channels)
    if pop.carry_bw.shape[1] != n_ch:
        raise ValueError(
            f"population carries cover {pop.carry_bw.shape[1]} channels but "
            f"cfg has {n_ch}; pass n_channels to make_population")
    base = jax.random.PRNGKey(cfg.seed + 1)
    if ks is None:
        k_total = max(1, d // 20)                  # 5% sparsity default
        ks = [k_total // 2, k_total // 4,
              k_total - k_total // 2 - k_total // 4]
    ks = (list(ks) + [0] * n_ch)[:n_ch]
    if mode == "topk":
        ks = [sum(ks)] + [0] * (n_ch - 1)
    k_cap = (1 if mode == "fedavg"
             else min(d, 1 << (max(1, sum(ks)) - 1).bit_length()))
    eta_fn = lambda t: cfg.lr * cfg.lr_decay_a / (cfg.lr_decay_a + t)

    pool_data, pool_n = _stack_device_data(task.device_data)
    n_shards = pop.n_shards

    device_phase = make_device_phase(
        cfg=cfg, loss_fn=task.loss_fn, base=base, mode=mode,
        backend=backend, scenario=scn, d=d, n_ch=n_ch)
    # donate the gathered cohort state (w_hat, anchor, ef, scen_carry):
    # each window consumes freshly assembled (M, .) buffers whose outputs
    # are scattered back to the host pools, so in-place update is always
    # legal here (same donation contract as BatchedEngine._window)
    phase_jit = jax.jit(device_phase, static_argnames=("k_cap",),
                        donate_argnums=(0, 1, 2, 3))

    # shared server half: one jitted program over the assembled (M, D)
    # update matrix, identical for every engine blocking; g is dead after
    # the call, params is not (params_before feeds mid-window evals)
    @functools.partial(jax.jit, donate_argnums=(1,))
    def _apply_server(params, g):
        flat = flatten_tree(params) - jnp.sum(g, axis=0) / g.shape[0]
        return unflatten_like(flat, params)

    # non-mean aggregators (docs/ARCHITECTURE.md §11): the same single
    # jitted server program for every blocking, so the sampled-cohort
    # bitwise rung extends to diloco/semi_sync unchanged.  Every window
    # the full cohort syncs, so the mask is all-true and the fold
    # unconditional; the cohort (not N) normalises the aggregate.
    agg = get_aggregator(cfg.aggregator)
    server_state = init_server_state(cfg, d) if agg.carries_state else None
    server_wall = 0.0
    if agg.name != "mean":
        alpha, cap = float(cfg.staleness_alpha), int(cfg.staleness_cap)
        out_lr, out_mu = float(cfg.outer_lr), float(cfg.outer_momentum)

        @functools.partial(jax.jit, donate_argnums=(1, 2))
        def _apply_server_ext(params, g, ef, t_comm, comp32, deadline,
                              state):
            m_c = g.shape[0]
            flat = flatten_tree(params)
            if agg.name == "diloco":
                new_flat, state = diloco_update(
                    flat, state, jnp.sum(g, axis=0) / m_c, jnp.bool_(True),
                    out_lr, out_mu)
            else:  # semi_sync: late-update mass back to the cohort's EF
                T = t_comm + comp32
                mask = jnp.ones((m_c,), bool)
                _, _, _, undeliv = staleness_schedule(T, deadline, mask,
                                                      alpha, cap)
                ef = jnp.where(undeliv[:, None] > 0,
                               ef + undeliv[:, None] * g, ef)
                g_now, contrib, _ = semi_sync_sums(g, T, mask, deadline,
                                                   alpha, cap)
                new_flat, state = semi_sync_update(
                    flat, state, g_now, contrib, jnp.bool_(True), m_c)
            return unflatten_like(new_flat, params), ef, state

    # shared keyed-subset eval (TAG_EVAL), mirroring LGCSimulator._record
    xe, ye = (jnp.asarray(task.eval_data[0]), jnp.asarray(task.eval_data[1]))
    n_eval = int(xe.shape[0])
    n_take = min(2048, n_eval)

    @jax.jit
    def _eval_at(params, t):
        key = stream_key(base, TAG_EVAL, t)
        idx = jax.random.randint(key, (n_take,), 0, n_eval)
        return (task.loss_fn(params, (xe[idx], ye[idx])),
                task.metric_fn(params, (xe[idx], ye[idx])))

    if engine == "sharded":
        from jax.sharding import PartitionSpec as P

        from repro.launch.compat import shard_map
        from repro.launch.mesh import fl_axis_name, make_host_mesh
        mesh = mesh if mesh is not None else make_host_mesh()
        axis = fl_axis_name(mesh)
        n_mesh = int(mesh.shape[axis])
        if m_cohort % n_mesh != 0:
            raise ValueError(f"cohort size {m_cohort} does not divide over "
                             f"{n_mesh} mesh devices on axis {axis!r}")
        shard, rep = P(axis), P()
        # args: w_hat, anchor, ef, scen_carry, data, n_dev, dev_ids,
        #       ts, etas, valid, sync_mask, ks_mat
        in_specs = (shard, shard, shard, shard, shard, shard, shard,
                    rep, rep, rep, shard, shard)
        out_specs = (shard, shard, shard, shard, shard)
        _programs: dict[tuple, Callable] = {}

        def run_phase(*args):
            sig = tuple(args[7].shape)          # window length -> program
            fn = _programs.get(sig)
            if fn is None:
                fn = jax.jit(shard_map(
                    functools.partial(device_phase, k_cap=k_cap),
                    mesh=mesh, in_specs=in_specs, out_specs=out_specs),
                    donate_argnums=(0, 1, 2, 3))
                _programs[sig] = fn
            return fn(*args)
    elif engine == "batched":
        def run_phase(*args):
            return phase_jit(*args, k_cap=k_cap)
    else:                                       # "loop": single-row blocks
        def run_phase(*args):
            rows = []
            for j in range(m_cohort):
                blk = tuple(
                    a if i in (7, 8, 9)         # ts/etas/valid are shared
                    else jax.tree_util.tree_map(lambda x: x[j:j + 1], a)
                    for i, a in enumerate(args))
                rows.append(phase_jit(*blk, k_cap=k_cap))
            return tuple(
                jax.tree_util.tree_map(
                    lambda *leaves: jnp.concatenate(leaves, axis=0), *parts)
                for parts in zip(*rows))

    hist = History()
    sync_mask = jnp.ones((m_cohort,), bool)
    ks_mat = jnp.broadcast_to(jnp.asarray(ks, jnp.int32)[None],
                              (m_cohort, n_ch)) + 0
    comp = comp_cost(scn.device_profile_at(0), h)
    t = 0
    while t < cfg.rounds:
        te = min(t + h, cfg.rounds)
        ids = sample_cohort(base, pop.sampler, pop.n, m_cohort, t,
                            pop.weights)
        shard_idx = jnp.asarray(ids % n_shards, jnp.int32)
        data_c = jax.tree_util.tree_map(lambda a: a[shard_idx], pool_data)
        n_dev_c = pool_n[shard_idx]
        dev_ids = jnp.asarray(ids, jnp.int32)
        flat0 = flatten_tree(params)
        w_hat_c = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (m_cohort,) + a.shape) + 0,
            params)
        anchor_c = jnp.broadcast_to(flat0[None], (m_cohort, d)) + 0
        ef_c = pop.ef_store.gather(ids)
        carry_c = ScenarioCarry(jnp.asarray(pop.carry_bw[ids]),
                                jnp.asarray(pop.carry_good[ids]))
        ts, etas, valid = _pad_pow2(t, te, eta_fn)

        _, carry_c, g, ef_c, costs = run_phase(
            w_hat_c, anchor_c, ef_c, carry_c, data_c, n_dev_c, dev_ids,
            ts, etas, valid, sync_mask, ks_mat)

        params_before = params
        if agg.name == "mean":
            deadline = None
            params = _apply_server(params, g)
        else:
            profs = [scn.device_profile_at(int(i)) for i in ids]
            deadline = (window_deadline(cfg, mode, d,
                                        [(h, ks, p) for p in profs])
                        if agg.uses_timing else 1.0)
            comp32 = jnp.asarray(
                [np.float32(comp_cost(p, h)["time_s"]) for p in profs],
                jnp.float32)
            params, ef_c, server_state = _apply_server_ext(
                params, g, ef_c, costs[:, 2], comp32,
                jnp.float32(deadline), server_state)

        def _rec(r, p_at):
            loss, acc = _eval_at(p_at, jnp.int32(r))
            hist.step.append(r)
            hist.loss.append(float(loss))
            hist.accuracy.append(float(acc))
            hist.energy_j.append(float(pop.spend[:, 0].sum()))
            hist.money.append(float(pop.spend[:, 1].sum()))
            hist.time_s.append(float(pop.spend[:, 2].max()))
            hist.uplink_mb.append(float(pop.spend[:, 3].sum()))
            hist.server_wall_s.append(float(server_wall))

        # eval points falling mid-window precede this window's sync, so
        # they are recorded against the pre-window params AND pre-window
        # spend (same rule as BatchedEngine.run); the window-end point
        # sees the new params and the window's costs
        for r in range(t, te - 1):
            if r % cfg.eval_every == 0 or r == cfg.rounds - 1:
                _rec(r, params_before)

        pop.ef_store.scatter(ids, ef_c)
        pop.carry_bw[ids] = np.asarray(carry_c.bw_log)
        pop.carry_good[ids] = np.asarray(carry_c.good)
        pop.participation[ids] += 1
        costs_np = np.asarray(costs, np.float64)
        t_wins = []
        for j, i in enumerate(ids):
            ccomp = (comp if scn.straggler is None
                     else comp_cost(scn.device_profile_at(int(i)), h))
            pop.spend[i, 0] += costs_np[j, 0] + ccomp["energy_j"]
            pop.spend[i, 1] += costs_np[j, 1] + ccomp["money"]
            pop.spend[i, 2] += costs_np[j, 2] + ccomp["time_s"]
            pop.spend[i, 3] += costs_np[j, 3] / 1e6
            t_wins.append(float(costs_np[j, 2]) + ccomp["time_s"])
        # simulated server wall (f64 host math, identical per blocking):
        # sync servers wait for the slowest cohort device, semi_sync for
        # at most the window deadline
        server_wall += (min(deadline, max(t_wins)) if agg.uses_timing
                        else max(t_wins))

        if (te - 1) % cfg.eval_every == 0 or te - 1 == cfg.rounds - 1:
            _rec(te - 1, params)
        t = te
    return hist
