"""The paper's evaluation models (§4.1): LR, CNN (MNIST) and char-RNN
(Shakespeare), as pure-pytree JAX models wrapped into ``FLTask``s.

Implemented from scratch (no flax): params are nested dicts of jnp arrays,
forward passes are plain functions -- the same convention used by the big
model zoo in :mod:`repro.models.transformer`.

The :data:`TASKS` registry (mirroring ``SCENARIOS`` in
:mod:`repro.core.scenario`) names the paper's three workloads --
``lr_mnist``, ``cnn_mnist``, ``rnn_shakespeare`` -- behind one
:func:`make_task` entry point; every registry task runs through all three
engines and inherits the loop~batched (allclose) / batched==sharded
(bitwise, gather mode) equivalence invariant
(tests/test_tasks.py::TestTaskEngineEquivalence; see
docs/ARCHITECTURE.md §5).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.fl import FLTask
from repro.core.scenario import Scenario, get_scenario, partition_fn
from repro.data.mnist import load_synthetic_mnist
from repro.data.shakespeare import VOCAB_SIZE, char_shards, load_shakespeare

Array = jax.Array


def _xent(logits: Array, y: Array) -> Array:
    logp = jax.nn.log_softmax(logits, -1)
    return -jnp.mean(jnp.take_along_axis(logp, y[..., None], -1))


def _acc(logits: Array, y: Array) -> Array:
    return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))


# ---------------------------------------------------------------------------
# LR on MNIST (Gortmaker 1994 / standard multinomial logistic regression)
# ---------------------------------------------------------------------------

def lr_init(key: Array) -> dict:
    k1, _ = jax.random.split(key)
    return {"w": jax.random.normal(k1, (784, 10)) * 0.01,
            "b": jnp.zeros((10,))}


def lr_logits(params: dict, x: Array) -> Array:
    return x.reshape(x.shape[0], -1) @ params["w"] + params["b"]


# ---------------------------------------------------------------------------
# CNN on MNIST (LeNet-style, as in FedML's MNIST CNN)
# ---------------------------------------------------------------------------

def cnn_init(key: Array) -> dict:
    ks = jax.random.split(key, 4)
    he = jax.nn.initializers.he_normal()
    return {
        "c1": he(ks[0], (3, 3, 1, 16)), "b1": jnp.zeros((16,)),
        "c2": he(ks[1], (3, 3, 16, 32)), "b2": jnp.zeros((32,)),
        "w1": he(ks[2], (7 * 7 * 32, 128)), "bw1": jnp.zeros((128,)),
        "w2": he(ks[3], (128, 10)), "bw2": jnp.zeros((10,)),
    }


def _conv3x3(z: Array, w: Array, b: Array) -> Array:
    """SAME 3x3 conv as shift-im2col: pad + 9 static slices + one matmul.

    ``lax.conv_general_dilated`` runs ~3 GFLOP/s on XLA:CPU while its dot
    kernels hit ~27, so the window program expresses the conv as the matmul
    it is: the patch matrix is 9 shifted views of the padded input
    concatenated on the channel axis, contracted against the (9*cin, cout)
    reshaped kernel.  Forward agrees with lax.conv to float reduction order
    (~1e-6); the engine ladder is unaffected because every engine runs this
    same formulation (docs/ARCHITECTURE.md §10).
    """
    bsz, h, wd, cin = z.shape
    zp = jnp.pad(z, ((0, 0), (1, 1), (1, 1), (0, 0)))
    patches = jnp.concatenate(
        [zp[:, i:i + h, j:j + wd, :] for i in range(3) for j in range(3)],
        axis=-1)
    return patches @ w.reshape(9 * cin, w.shape[-1]) + b


def _pool2x2(z: Array) -> Array:
    """2x2/2 max pool as a reshape + max (cheaper than reduce_window on CPU)."""
    bsz, h, wd, c = z.shape
    return jnp.max(z.reshape(bsz, h // 2, 2, wd // 2, 2, c), axis=(2, 4))


def cnn_logits(params: dict, x: Array) -> Array:
    # relu AFTER pool: max and relu commute exactly (both are max-chains),
    # and the relu then touches a 4x smaller tensor in forward and backward
    z = jax.nn.relu(_pool2x2(_conv3x3(x, params["c1"], params["b1"])))
    z = jax.nn.relu(_pool2x2(_conv3x3(z, params["c2"], params["b2"])))
    z = z.reshape(z.shape[0], -1)
    z = jax.nn.relu(z @ params["w1"] + params["bw1"])
    return z @ params["w2"] + params["bw2"]


# ---------------------------------------------------------------------------
# char-RNN on Shakespeare (GRU, as in LEAF/FedML Shakespeare)
# ---------------------------------------------------------------------------

_RNN_EMB, _RNN_HID = 64, 128


def rnn_init(key: Array) -> dict:
    ks = jax.random.split(key, 5)
    glorot = jax.nn.initializers.glorot_normal()
    v, e, h = VOCAB_SIZE, _RNN_EMB, _RNN_HID
    return {
        "emb": jax.random.normal(ks[0], (v, e)) * 0.02,
        "wz": glorot(ks[1], (e + h, h)), "bz": jnp.zeros((h,)),
        "wr": glorot(ks[2], (e + h, h)), "br": jnp.zeros((h,)),
        "wh": glorot(ks[3], (e + h, h)), "bh": jnp.zeros((h,)),
        "out": glorot(ks[4], (h, v)), "bo": jnp.zeros((v,)),
    }


def rnn_logits(params: dict, x: Array) -> Array:
    """x: (B, S) int32 -> (B, S, V) next-char logits."""
    emb = params["emb"][x]                       # (B,S,E)
    b = x.shape[0]
    h0 = jnp.zeros((b, _RNN_HID))

    def cell(h, et):
        ze = jnp.concatenate([et, h], -1)
        z = jax.nn.sigmoid(ze @ params["wz"] + params["bz"])
        r = jax.nn.sigmoid(ze @ params["wr"] + params["br"])
        cand = jnp.tanh(jnp.concatenate([et, r * h], -1) @ params["wh"]
                        + params["bh"])
        h = (1 - z) * h + z * cand
        return h, h
    _, hs = jax.lax.scan(cell, h0, jnp.swapaxes(emb, 0, 1))
    hs = jnp.swapaxes(hs, 0, 1)                  # (B,S,H)
    return hs @ params["out"] + params["bo"]


# ---------------------------------------------------------------------------
# FLTask factories
# ---------------------------------------------------------------------------

def make_mnist_task(model: str = "lr", m_devices: int = 3, n_train: int = 6000,
                    seed: int = 0, partition: str = "iid",
                    alpha: float = 0.5,
                    scenario: str | Scenario | None = None) -> FLTask:
    """``partition``/``alpha`` select the device data sharding ("iid",
    "noniid", "dirichlet", "quantity"); passing ``scenario`` (a registry
    name or Scenario) takes the sharding from the scenario instead, so the
    same object that drives the engines' channel dynamics also shapes the
    task's statistical heterogeneity."""
    if scenario is not None:
        scn = get_scenario(scenario)
        partition, alpha = scn.partition, scn.alpha
    (xtr, ytr), (xte, yte) = load_synthetic_mnist(n_train=n_train, seed=seed)
    shards = partition_fn(Scenario(partition=partition, alpha=alpha))(
        xtr, ytr, m_devices, seed)
    init, logits = (lr_init, lr_logits) if model == "lr" else (cnn_init, cnn_logits)

    def loss_fn(params, batch):
        x, y = batch
        return _xent(logits(params, x), y)

    def metric_fn(params, batch):
        x, y = batch
        return _acc(logits(params, x), y)
    return FLTask(init, loss_fn, metric_fn, shards, (xte, yte),
                  name=f"{model}-mnist")


def make_shakespeare_task(m_devices: int = 3, seq: int = 48, seed: int = 0,
                          n_train: int | None = None, n_eval: int = 1024,
                          partition: str = "dirichlet", alpha: float = 0.5,
                          scenario: str | Scenario | None = None,
                          test_frac: float = 0.15) -> FLTask:
    """Char-RNN task with the same partition/scenario surface as
    :func:`make_mnist_task`: sequence windows are drawn deterministically
    from a train split that is disjoint from the held-out eval split
    (:func:`repro.data.shakespeare.char_shards`), labeled by corpus region
    (the "which play" proxy), and dealt to devices by any registered
    partitioner.  The default Dirichlet-over-regions partition keeps the
    natural different-plays non-IID-ness of the seed task as an *exact*
    partition (all ``n_train`` windows train, each on exactly one device --
    the legacy ``"noniid"`` partitioner subsamples and may duplicate);
    passing ``scenario`` takes partition and alpha from the scenario,
    exactly like MNIST."""
    if scenario is not None:
        scn = get_scenario(scenario)
        partition, alpha = scn.partition, scn.alpha
    stream = load_shakespeare(seed=seed)
    n_train = 2000 * m_devices if n_train is None else n_train
    shards, eval_data = char_shards(
        stream, m_devices, seq=seq, n_train=n_train, n_eval=n_eval,
        seed=seed, test_frac=test_frac,
        partition_fn=partition_fn(Scenario(partition=partition,
                                           alpha=alpha)))

    def loss_fn(params, batch):
        x, y = batch
        return _xent(rnn_logits(params, x), y)

    def metric_fn(params, batch):
        x, y = batch
        return _acc(rnn_logits(params, x), y)
    return FLTask(rnn_init, loss_fn, metric_fn, shards, eval_data,
                  name="rnn-shakespeare")


# ---------------------------------------------------------------------------
# the task zoo registry (mirrors SCENARIOS in repro.core.scenario)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """One registry workload: which model/dataset, and the partition the
    task defaults to when no scenario overrides it.

    ``dataset="tokens"`` marks the big-model stack: its factory returns an
    :class:`repro.models.lgc_transformer.LGCTransformerTask` (the shard_map
    LGC engine itself) instead of an ``FLTask`` for the stacked engines --
    at 1.28e8 parameters an (M, d) stacked tree is not a thing you
    materialise.  See docs/ARCHITECTURE.md §12.
    """
    name: str
    model: str              # "lr" | "cnn" | "gru" | "qwen2"
    dataset: str            # "mnist" | "shakespeare" | "tokens"
    partition: str          # default data sharding (scenario= overrides)

    @property
    def is_engine_task(self) -> bool:
        """True when ``make`` returns an FLTask the loop/batched/sharded
        engines can run (the tokens-backed tasks are their own engine)."""
        return self.dataset != "tokens"

    def make(self, m_devices: int = 3, seed: int = 0,
             scenario: str | Scenario | None = None, **kw):
        if self.dataset == "tokens":
            from repro.models.lgc_transformer import make_qwen2_100m_task
            return make_qwen2_100m_task(m_devices, seed=seed,
                                        scenario=scenario, **kw)
        kw.setdefault("partition", self.partition)
        if self.dataset == "mnist":
            return make_mnist_task(self.model, m_devices, seed=seed,
                                   scenario=scenario, **kw)
        return make_shakespeare_task(m_devices, seed=seed,
                                     scenario=scenario, **kw)


TASKS: dict[str, TaskSpec] = {
    # the paper's §4.1 evaluation zoo: LR and CNN on (synthetic) MNIST, a
    # GRU char-RNN on Shakespeare
    "lr_mnist": TaskSpec("lr_mnist", model="lr", dataset="mnist",
                         partition="iid"),
    "cnn_mnist": TaskSpec("cnn_mnist", model="cnn", dataset="mnist",
                          partition="iid"),
    "rnn_shakespeare": TaskSpec("rnn_shakespeare", model="gru",
                                dataset="shakespeare",
                                partition="dirichlet"),
    # the production-scale stack (ROADMAP item 2): ~128M-param qwen2 behind
    # the shard_map LGC step, FL axis x model axis on one mesh
    "qwen2_100m": TaskSpec("qwen2_100m", model="qwen2", dataset="tokens",
                           partition="iid"),
}

ENGINE_TASKS: tuple[str, ...] = tuple(
    sorted(n for n, s in TASKS.items() if s.is_engine_task))


def make_task(name: str, m_devices: int = 3, seed: int = 0,
              scenario: str | Scenario | None = None, **kw):
    """One entry point for the whole zoo: resolve a registry name and build
    the task (``scenario=`` shapes the data exactly as in the per-dataset
    factories; extra kwargs pass through, e.g. ``n_train``/``seq``, or
    ``preset``/``sparsity``/``aggregate`` for ``qwen2_100m``)."""
    try:
        spec = TASKS[name]
    except KeyError:
        raise ValueError(
            f"unknown task {name!r}; registered: {sorted(TASKS)}") from None
    return spec.make(m_devices, seed=seed, scenario=scenario, **kw)
