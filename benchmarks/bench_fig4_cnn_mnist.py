"""Paper Figure 4: CNN on MNIST -- convergence + resources vs baselines."""
from __future__ import annotations

import argparse
import json

from .bench_fig3_lr_mnist import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    res = run(model="cnn", rounds=args.rounds, n_train=2000)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
