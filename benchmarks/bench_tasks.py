"""Task zoo sweep: throughput + smoke-budget accuracy for every registry
task (repro.models.paper_models.TASKS) on the batched engine.

The perf trajectory (BENCH_sim.json, BENCH_sharded.json) has so far only
ever measured ``lr_mnist``; the paper's evaluation (§4.1) spans LR, CNN and
a char-RNN.  This bench runs each registry task end-to-end under the fixed
LGC controller and records the final loss/accuracy next to
``device_steps_per_s`` -- the *steady-state* window throughput, measured
with the compile-excluding chained-window pattern shared with
``bench_sharded_scaling`` -- so a kernel or engine change that only helps
flat float models can't hide (``wall_s`` keeps the end-to-end time,
compile included, for reference).  Rows land in ``BENCH_tasks.json`` via
``benchmarks/run.py --smoke`` (CI uploads it as an artifact).
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.core import (FLConfig, FixedController, LGCSimulator,
                        run_baseline, tree_size)
from repro.core.fl_batched import BatchedEngine
from repro.models.paper_models import TASKS, make_task

from .bench_sharded_scaling import _steady_window_rate
from .common import emit


# per-task shape knobs: keep every task inside the smoke budget while still
# doing enough optimisation steps for the accuracy column to mean something
_TASK_KW = {
    "lr_mnist": dict(n_train=2000),
    "cnn_mnist": dict(n_train=1200),
    "rnn_shakespeare": dict(n_train=2000, seq=32),
}


def run(tasks=None, m: int = 8, rounds: int = 40, batch_size: int = 32,
        emit_csv: bool = True) -> dict:
    names = list(tasks or TASKS)
    rows = []
    for name in names:
        task = make_task(name, m_devices=m, **_TASK_KW.get(name, {}))
        d = tree_size(task.init(jax.random.PRNGKey(0)))
        cfg = FLConfig(rounds=rounds, eval_every=max(rounds // 4, 1),
                       batch_size=batch_size)
        t0 = time.time()
        hist = run_baseline(task, cfg, "lgc", h=4, engine="batched")
        wall = time.time() - t0
        # steady-state throughput: chain windows of one compiled program and
        # time everything after the first call (compile excluded), same
        # methodology as bench_sharded_scaling
        sim = LGCSimulator(task, cfg,
                           [FixedController(4, [200, 300, 400])] * m,
                           mode="lgc", engine="batched")
        eng = BatchedEngine(sim)
        rate, _ = _steady_window_rate(sim, eng, m, h=4,
                                      k_windows=max(rounds // 4, 4))
        rows.append({
            "task": name, "engine": "batched", "m_devices": m,
            "rounds": rounds, "params_d": d, "wall_s": round(wall, 3),
            "device_steps_per_s": round(rate, 1),
            "final_loss": round(hist.loss[-1], 4),
            "final_accuracy": round(hist.accuracy[-1], 4),
            "uplink_mb": round(hist.uplink_mb[-1], 4),
        })
        if emit_csv:
            emit(f"task_{name}", wall * 1e6 / rounds,
                 f"device_steps_per_s={rows[-1]['device_steps_per_s']};"
                 f"acc={rows[-1]['final_accuracy']};"
                 f"loss={rows[-1]['final_loss']};d={d}")
    return {"benchmark": "tasks", "m_devices": m, "rounds": rounds,
            "rows": rows}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--m", type=int, default=8)
    ap.add_argument("--tasks", default=None,
                    help="comma-separated registry names (default: all)")
    ap.add_argument("--out", default="BENCH_tasks.json")
    args = ap.parse_args()
    names = args.tasks.split(",") if args.tasks else None
    res = run(tasks=names, m=args.m, rounds=args.rounds)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
