"""Scenario subsystem (repro.core.scenario): dynamic environments as
first-class citizens of the engine-equivalence invariant.

Every named scenario in the registry -- Gauss-Markov bandwidth,
Gilbert-Elliott burst availability, device dropout/stragglers, Dirichlet
data skew -- must run through the loop, batched and sharded engines and
produce the same History: allclose for loop-vs-batched (float reduction
order differs), BIT-identical for batched-vs-sharded with the gather server
reduce, allclose for psum.  The sharded check runs at every mesh size the
process can build (1-way and the full device count), so the test-sharded CI
lane exercises >= 2 shard counts.

Plus: Hypothesis property tests for all four partitioners, chain
stationarity (catches sign/decay-rate bugs in the carry update), and the
error-feedback graceful-degradation regression under ``gilbert_flaky``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SCENARIOS, FLConfig, FixedController, LGCSimulator,
                        get_scenario, make_fleet_ddpg, run_baseline,
                        tree_size)
from repro.core.channels import DEFAULT_CHANNELS, stack_specs
from repro.core.scenario import (TAG_CHANNEL, GilbertElliottSpec, Scenario,
                                 init_carry, sample_from_carry, step_carry,
                                 stream_key)
from repro.data import (partition_dirichlet, partition_iid, partition_noniid,
                        partition_quantity_skew, skew_score)
from repro.launch.mesh import make_host_mesh
from repro.models.paper_models import make_mnist_task

from _hypothesis_compat import given, settings, st  # hypothesis or fallback

N_DEV = len(jax.devices())
SHARD_COUNTS = sorted({1, N_DEV})        # >= 2 mesh sizes when devices allow
M = 8                                    # divides every power-of-two mesh

_TASKS: dict = {}
_BATCHED: dict = {}


def _cfg(name: str) -> FLConfig:
    return FLConfig(rounds=18, eval_every=6, scenario=name)


def _task(name: str):
    """One task per (partition, alpha) -- scenarios sharing a data
    distribution share the task, so e.g. static vs gilbert_flaky Histories
    are directly comparable."""
    scn = get_scenario(name)
    key = (scn.partition, scn.alpha)
    if key not in _TASKS:
        _TASKS[key] = make_mnist_task("lr", m_devices=M, n_train=1600,
                                      scenario=name)
    return _TASKS[key]


def _batched_hist(name: str):
    if name not in _BATCHED:
        _BATCHED[name] = run_baseline(_task(name), _cfg(name), "lgc", h=4,
                                      engine="batched")
    return _BATCHED[name]


class TestScenarioEngineEquivalence:
    """loop == batched == sharded for every registry scenario."""

    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_loop_matches_batched(self, name):
        h_loop = run_baseline(_task(name), _cfg(name), "lgc", h=4,
                              engine="loop")
        h_bat = _batched_hist(name)
        assert h_loop.step == h_bat.step
        np.testing.assert_allclose(h_bat.loss, h_loop.loss, atol=1e-4)
        np.testing.assert_allclose(h_bat.accuracy, h_loop.accuracy,
                                   atol=1e-4)
        np.testing.assert_allclose(h_bat.uplink_mb, h_loop.uplink_mb,
                                   atol=1e-4)
        np.testing.assert_allclose(h_bat.energy_j, h_loop.energy_j,
                                   rtol=1e-5)
        np.testing.assert_allclose(h_bat.time_s, h_loop.time_s, rtol=1e-5)

    @pytest.mark.parametrize("n_shards", SHARD_COUNTS)
    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_sharded_bit_identical(self, name, n_shards):
        """gather-mode History carries the exact same floats at every mesh
        size -- the scenario carry is sharded state, but the chains are keyed
        by global device id, so the shard layout cannot matter."""
        h_sh = run_baseline(_task(name), _cfg(name), "lgc", h=4,
                            engine="sharded", mesh=make_host_mesh(n_shards))
        assert h_sh.asdict() == _batched_hist(name).asdict()

    @pytest.mark.parametrize("name", ["markov_urban", "gilbert_flaky"])
    def test_psum_allclose(self, name):
        h_ps = run_baseline(_task(name), _cfg(name), "lgc", h=4,
                            engine="sharded", server_reduce="psum")
        h_bat = _batched_hist(name)
        np.testing.assert_allclose(h_ps.loss, h_bat.loss, atol=1e-4)
        np.testing.assert_allclose(h_ps.uplink_mb, h_bat.uplink_mb,
                                   atol=1e-4)

    @pytest.mark.parametrize("mode", ["fedavg", "lgc_q8"])
    def test_other_modes_under_dropout(self, mode):
        """Dropout folds into ch.up before the mode branches, so the dense
        fedavg path (best-up-channel choice) and the QSGD path (quantization
        residual into EF) must stay engine-equivalent under gilbert_flaky."""
        cfg = FLConfig(rounds=12, eval_every=6, scenario="gilbert_flaky")
        task = _task("gilbert_flaky")
        h_loop = run_baseline(task, cfg, mode, h=4, engine="loop")
        h_bat = run_baseline(task, cfg, mode, h=4, engine="batched")
        h_sh = run_baseline(task, cfg, mode, h=4, engine="sharded")
        np.testing.assert_allclose(h_bat.loss, h_loop.loss, atol=1e-4)
        np.testing.assert_allclose(h_bat.uplink_mb, h_loop.uplink_mb,
                                   atol=1e-4)
        assert h_sh.asdict() == h_bat.asdict()

    def test_fedavg_total_outage_uploads_nothing(self):
        """A device with every channel down must lose its dense FedAvg
        upload entirely -- no bytes billed, no update applied (FedAvg has
        no error feedback to carry the mass)."""
        blackout = Scenario(name="blackout", gilbert_elliott=GilbertElliottSpec(
            p_gb=1.0, p_bg=1e-9))           # stationary availability ~ 0
        cfg = FLConfig(rounds=8, eval_every=4, scenario=blackout)
        task = _task("static")
        h_loop = run_baseline(task, cfg, "fedavg", h=4, engine="loop")
        h_bat = run_baseline(task, cfg, "fedavg", h=4, engine="batched")
        h_sh = run_baseline(task, cfg, "fedavg", h=4, engine="sharded")
        assert h_bat.uplink_mb[-1] == 0.0
        assert h_bat.energy_j[-1] == pytest.approx(h_loop.energy_j[-1])
        np.testing.assert_allclose(h_bat.loss, h_loop.loss, atol=1e-4)
        assert h_sh.asdict() == h_bat.asdict()

    def test_heterogeneous_gaps_dynamic_scenario(self):
        """Ragged sync sets + evolving chains: the chunked window scan must
        advance the carry through exactly the same rounds as the loop."""
        cfg = FLConfig(rounds=25, eval_every=8, max_gap=6,
                       scenario="markov_urban")

        def ctrls():
            return [FixedController(2 + (m % 5), [200, 300, 400])
                    for m in range(M)]
        hists = {}
        for engine in ("loop", "batched", "sharded"):
            hists[engine] = LGCSimulator(_task("markov_urban"), cfg, ctrls(),
                                         mode="lgc", engine=engine).run()
        np.testing.assert_allclose(hists["batched"].loss,
                                   hists["loop"].loss, atol=1e-4)
        np.testing.assert_allclose(hists["batched"].uplink_mb,
                                   hists["loop"].uplink_mb, atol=1e-4)
        assert hists["sharded"].asdict() == hists["batched"].asdict()

    def test_ddpg_fleet_dynamic_scenario_bit_identical(self):
        """The full learned control plane on a dynamic scenario: scenario
        costs feed the controller states, so sharded-vs-batched bitwise
        History proves the whole feedback loop is shard-layout free."""
        task = _task("markov_urban")
        d = tree_size(task.init(jax.random.PRNGKey(0)))
        cfg = FLConfig(rounds=20, eval_every=8, scenario="markov_urban")
        h_bat = LGCSimulator(task, cfg, make_fleet_ddpg(M, d), mode="lgc",
                             engine="batched").run()
        h_sh = LGCSimulator(task, cfg, make_fleet_ddpg(M, d), mode="lgc",
                            engine="sharded").run()
        assert h_sh.asdict() == h_bat.asdict()

    def test_dropout_actually_reduces_uplink(self):
        """static and gilbert_flaky share task + sync schedule; dropped
        uplinks must show up as strictly less transmitted traffic."""
        h_static = _batched_hist("static")
        h_flaky = _batched_hist("gilbert_flaky")
        assert h_flaky.uplink_mb[-1] < h_static.uplink_mb[-1]


# ---------------------------------------------------------------------------
# partitioner properties
# ---------------------------------------------------------------------------

_N = 400
_PRNG = np.random.default_rng(99)
_PX = np.stack([np.arange(_N), np.arange(_N)], 1).astype(np.float32)
_PY = _PRNG.integers(0, 10, _N).astype(np.int32)


def _ids(shards):
    """Original sample indices of every shard (x rows encode their index)."""
    return [s[0][:, 0].astype(np.int64) for s in shards]


def _assert_exact_partition(shards, n):
    ids = np.concatenate(_ids(shards))
    assert len(ids) == n                      # nothing lost
    assert len(np.unique(ids)) == n           # nothing duplicated
    assert all(s[1].size > 0 for s in shards)  # every device non-empty


def _assert_deterministic(fn, m, **kw):
    a, b = fn(_PX, _PY, m, **kw), fn(_PX, _PY, m, **kw)
    for (xa, ya), (xb, yb) in zip(a, b):
        np.testing.assert_array_equal(xa, xb)
        np.testing.assert_array_equal(ya, yb)


class TestPartitionerProperties:
    @settings(max_examples=12, deadline=None)
    @given(st.integers(2, 16), st.integers(0, 10_000), st.integers(1, 300))
    def test_dirichlet_exact_partition(self, m, seed, alpha100):
        shards = partition_dirichlet(_PX, _PY, m, alpha=alpha100 / 100,
                                     seed=seed)
        _assert_exact_partition(shards, _N)

    @settings(max_examples=12, deadline=None)
    @given(st.integers(2, 16), st.integers(0, 10_000), st.integers(1, 300))
    def test_quantity_skew_exact_partition(self, m, seed, alpha100):
        shards = partition_quantity_skew(_PX, _PY, m, alpha=alpha100 / 100,
                                         seed=seed)
        _assert_exact_partition(shards, _N)

    @settings(max_examples=12, deadline=None)
    @given(st.integers(1, 16), st.integers(0, 10_000))
    def test_iid_exact_partition(self, m, seed):
        shards = partition_iid(_PX, _PY, m, seed=seed)
        _assert_exact_partition(shards, _N)

    @settings(max_examples=8, deadline=None)
    @given(st.integers(2, 8), st.integers(0, 10_000))
    def test_noniid_no_duplicates_within_device(self, m, seed):
        """The legacy label-subset partitioner subsamples (not an exact
        partition by design) but must stay duplicate-free per device,
        non-empty, and label-restricted."""
        shards = partition_noniid(_PX, _PY, m, classes_per_device=4,
                                  seed=seed)
        assert len(shards) == m
        for ids, (_, y) in zip(_ids(shards), shards):
            assert y.size > 0
            assert len(np.unique(ids)) == len(ids)
            assert len(np.unique(y)) <= 4

    def test_deterministic_per_seed(self):
        _assert_deterministic(partition_dirichlet, 6, alpha=0.3, seed=11)
        _assert_deterministic(partition_quantity_skew, 6, alpha=0.3, seed=11)
        _assert_deterministic(partition_iid, 6, seed=11)
        _assert_deterministic(partition_noniid, 6, seed=11)

    def test_dirichlet_alpha_direction(self):
        """Low alpha => high label skew; high alpha => near-IID."""
        lo = np.mean([skew_score(partition_dirichlet(_PX, _PY, 10,
                                                     alpha=0.1, seed=s))
                      for s in range(3)])
        hi = np.mean([skew_score(partition_dirichlet(_PX, _PY, 10,
                                                     alpha=100.0, seed=s))
                      for s in range(3)])
        assert lo > hi + 0.2

    def test_quantity_skew_alpha_direction(self):
        """Low alpha => unequal shard sizes (max/min ratio grows)."""
        def imbalance(alpha):
            sizes = [y.size for _, y in partition_quantity_skew(
                _PX, _PY, 10, alpha=alpha, seed=4)]
            return max(sizes) / min(sizes)
        assert imbalance(0.1) > imbalance(100.0) * 2

    def test_more_devices_than_samples_raises(self):
        with pytest.raises(ValueError):
            partition_quantity_skew(_PX[:3], _PY[:3], 5)


# ---------------------------------------------------------------------------
# chain stationarity (catches sign/decay-rate bugs in the carry update)
# ---------------------------------------------------------------------------

class TestChainStationarity:
    T, M_CH = 2000, 32

    def _rollout(self, scn):
        consts = stack_specs(DEFAULT_CHANNELS)
        base = jax.random.PRNGKey(7)
        dev_ids = jnp.arange(self.M_CH, dtype=jnp.int32)
        n_ch = len(DEFAULT_CHANNELS)
        carry = jax.vmap(lambda i: init_carry(scn, base, i, n_ch))(dev_ids)

        def body(c, t):
            c = jax.vmap(
                lambda cc, i: step_carry(scn, base, cc, t, i,
                                         jnp.bool_(True)))(c, dev_ids)
            s = jax.vmap(
                lambda cc, i: sample_from_carry(
                    scn, consts, cc, stream_key(base, TAG_CHANNEL, t, i)))(
                c, dev_ids)
            return c, (s.bandwidth_mb_s, s.up)

        _, (bw, up) = jax.lax.scan(body, carry,
                                   jnp.arange(self.T, dtype=jnp.int32))
        return np.asarray(bw), np.asarray(up)   # (T, M, C)

    def test_gauss_markov_long_run_mean_is_nominal(self):
        scn = get_scenario("markov_urban")
        bw, _ = self._rollout(scn)
        nominal = np.array([c.bandwidth_mb_s for c in DEFAULT_CHANNELS])
        emp = bw.mean((0, 1))
        np.testing.assert_allclose(emp, nominal, rtol=0.10)

    def test_gauss_markov_autocorrelation_matches_rho(self):
        """Lag-1 autocorrelation of the log-bandwidth deviation equals the
        spec's rho -- a sign or decay-rate bug in the carry update flips or
        collapses this immediately."""
        scn = get_scenario("markov_urban")
        bw, _ = self._rollout(scn)
        x = np.log(bw)                           # (T, M, C) log-bandwidth
        x = x - x.mean(0, keepdims=True)
        num = (x[1:] * x[:-1]).sum()
        den = (x ** 2).sum()
        rho_hat = num / den
        assert abs(rho_hat - scn.gauss_markov.rho) < 0.05

    def test_gilbert_elliott_stationary_availability(self):
        for name in ("markov_urban", "gilbert_flaky"):
            scn = get_scenario(name)
            _, up = self._rollout(scn)
            pi = scn.gilbert_elliott.stationary_availability
            assert abs(up.mean() - pi) < 0.04, name

    def test_gilbert_elliott_losses_are_bursty(self):
        """P(down at t+1 | down at t) must exceed the unconditional down
        rate -- the whole point of the two-state chain vs IID Bernoulli."""
        scn = get_scenario("gilbert_flaky")
        _, up = self._rollout(scn)
        down = ~up
        p_down = down.mean()
        p_down_given_down = (down[1:] & down[:-1]).sum() / down[:-1].sum()
        assert p_down_given_down > p_down + 0.15

    def test_static_scenario_bitwise_matches_seed_model(self):
        """The "static" registry entry must reproduce channels.py's
        memoryless sampler exactly -- same sub-keys, same variates."""
        from repro.core.channels import sample_channels_from
        scn = get_scenario("static")
        consts = stack_specs(DEFAULT_CHANNELS)
        base = jax.random.PRNGKey(3)
        carry = init_carry(scn, base, jnp.int32(4), len(DEFAULT_CHANNELS))
        key = stream_key(base, TAG_CHANNEL, 17, 4)
        a = sample_from_carry(scn, consts, carry, key)
        b = sample_channels_from(key, consts)
        for xa, xb in zip(a, b):
            np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


# ---------------------------------------------------------------------------
# error feedback under burst loss + dropout (graceful degradation)
# ---------------------------------------------------------------------------

class TestErrorFeedbackUnderDropout:
    def test_gilbert_flaky_ef_bounded_and_converges(self):
        """channels.py's docstring claims the layered code degrades
        gracefully when channels drop layers.  Under gilbert_flaky (bursty
        outages + whole-device dropout) the EF residual must stay bounded --
        undelivered mass is retransmitted, not accumulated forever -- and
        the run must still learn."""
        task = make_mnist_task("lr", m_devices=M, n_train=2000)
        ctrls = [FixedController(4, [200, 300, 400]) for _ in range(M)]
        cfg = FLConfig(rounds=60, eval_every=20, scenario="gilbert_flaky")
        sim = LGCSimulator(task, cfg, ctrls, mode="lgc", engine="loop")
        hist = sim.run()
        assert hist.loss[-1] < hist.loss[0] - 0.2          # still converges
        ef_norms = np.array([float(jnp.linalg.norm(e.e)) for e in sim.ef])
        assert np.all(np.isfinite(ef_norms))
        # bounded: the error memory stays on the scale of one model update
        # (||e|| <= ||params|| is a generous ceiling; divergence would blow
        # through it within a few missed syncs)
        from repro.core import flatten_tree
        p_norm = float(jnp.linalg.norm(flatten_tree(sim.params)))
        assert ef_norms.max() < max(1.0, p_norm)


# ---------------------------------------------------------------------------
# registry / spec plumbing
# ---------------------------------------------------------------------------

class TestScenarioRegistry:
    def test_unknown_name_raises(self):
        with pytest.raises(ValueError, match="unknown scenario"):
            get_scenario("nope")
        # the simulator resolves the name at construction, not mid-run
        with pytest.raises(ValueError, match="unknown scenario"):
            LGCSimulator(_task("static"), FLConfig(scenario="nope"),
                         [FixedController(4, [1, 1, 1])] * M)

    def test_default_is_static(self):
        assert get_scenario(None).is_static
        assert get_scenario(FLConfig().scenario).is_static
        assert not get_scenario("markov_urban").is_static

    def test_scenario_object_passthrough(self):
        scn = Scenario(name="custom")
        assert get_scenario(scn) is scn

    def test_drop_probs_flaky_pattern(self):
        scn = get_scenario("gilbert_flaky")
        p = np.asarray(scn.drop_probs(jnp.arange(8, dtype=jnp.int32)))
        assert p[0] == p[4] == scn.dropout.flaky_prob
        assert p[1] == p[2] == scn.dropout.base_prob

    def test_straggler_profiles(self):
        scn = get_scenario("mobile_noniid")
        profiles = scn.device_profiles(8)
        slow = scn.straggler.slowdown
        assert profiles[0].comp_time_per_step_s == pytest.approx(
            profiles[1].comp_time_per_step_s * slow)
        assert profiles[4].comp_j_per_step == profiles[0].comp_j_per_step

    def test_registry_names_are_consistent(self):
        for name, scn in SCENARIOS.items():
            assert scn.name == name
