"""Phi-3-vision-4.2B [hf:microsoft/Phi-3-vision-128k-instruct] -- phi3-mini
backbone + CLIP frontend.  Vision encoder is a STUB: input_specs() feeds 576
precomputed patch embeddings (B,576,1024) through a learned projector
(the assignment's modality carve-out, DESIGN.md §4)."""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b", arch_type="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32_064,
    n_prefix_tokens=576,                       # CLIP ViT-L/14 @ 336px
    mlp="swiglu", norm="rmsnorm",
    fsdp=True,
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="phi3v-smoke", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=4, d_ff=512, vocab_size=512, n_prefix_tokens=16,
        fsdp=False, remat=False, attn_q_chunk=64)
