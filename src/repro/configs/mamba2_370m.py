"""Mamba2-370m [arXiv:2405.21060] -- attention-free SSD (state-space
duality).  LGC applies unchanged (gradient-space technique); long_500k runs
natively with O(1) recurrent state (DESIGN.md §4)."""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m", arch_type="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50_280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=128,
    use_rope=False, norm="rmsnorm",
    source="arXiv:2405.21060",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="mamba2-smoke", n_layers=2, d_model=128, ssm_state=16,
        ssm_head_dim=32, ssm_chunk=16, vocab_size=512, remat=False)
