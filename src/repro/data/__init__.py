"""Data pipelines: synthetic MNIST, embedded Shakespeare, LM token streams."""
from .mnist import load_synthetic_mnist, partition_iid, partition_noniid
from .shakespeare import CHAR_VOCAB, char_batches, load_shakespeare
from .tokens import TokenPipeline, synthetic_token_batch

__all__ = [
    "load_synthetic_mnist", "partition_iid", "partition_noniid",
    "CHAR_VOCAB", "char_batches", "load_shakespeare",
    "TokenPipeline", "synthetic_token_batch",
]
