"""Pytree optimizers (no optax in the container): SGD, SGD-momentum, AdamW."""
from .optimizers import (AdamWState, OptState, SGDMState, adamw_init,
                         apply_updates, get_optimizer, global_norm, sgd_init,
                         sgdm_init)

__all__ = ["AdamWState", "OptState", "SGDMState", "adamw_init",
           "apply_updates", "get_optimizer", "global_norm", "sgd_init",
           "sgdm_init"]
