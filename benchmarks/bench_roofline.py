"""Roofline table formatter: reads the dry-run JSONL files and emits the
EXPERIMENTS.md §Roofline markdown table + CSV lines.

  PYTHONPATH=src python -m benchmarks.bench_roofline \
      --jsonl results_singlepod.jsonl --markdown
"""
from __future__ import annotations

import argparse
import json

from .common import emit


def load(paths):
    rows = []
    for p in paths:
        with open(p) as f:
            for line in f:
                rows.append(json.loads(line))
    return rows


def fmt_row(r) -> str:
    ms = lambda t: f"{t*1e3:.2f}"
    fix = ""
    total_mem = r["memory_args_gb"] + r["memory_temp_gb"]
    one_sentence = {
        "compute": "raise MXU utilisation (larger fused matmul tiles, "
                   "less remat recompute)",
        "memory": "cut HBM traffic: flash-style attention (no materialised "
                  "probs), bf16 intermediates, fewer converts",
        "collective": "reshard to cut all-gathers (expert-parallel a2a / "
                      "head-aligned layouts) or overlap with compute",
    }[r["bottleneck"]]
    return (f"| {r['arch']} | {r['shape']} | {r['mode']} | "
            f"{ms(r['t_compute'])} | {ms(r['t_memory'])} | "
            f"{ms(r['t_collective'])} | **{r['bottleneck']}** | "
            f"{r['model_flops_total']:.3g} | {r['useful_ratio']:.3f} | "
            f"{total_mem:.1f} | {one_sentence} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", nargs="+",
                    default=["results_singlepod.jsonl"])
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = load(args.jsonl)
    if args.markdown:
        print("| arch | shape | mode | Tc (ms) | Tm (ms) | Tcoll (ms) | "
              "dominant | MODEL_FLOPS | useful | mem GB | next lever |")
        print("|---|---|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            print(fmt_row(r))
    else:
        for r in rows:
            emit(f"roofline_{r['arch']}_{r['shape']}_{r['mode']}",
                 r["t_compute"] * 1e6,
                 f"tm_us={r['t_memory']*1e6:.0f};"
                 f"tcoll_us={r['t_collective']*1e6:.0f};"
                 f"dom={r['bottleneck']};useful={r['useful_ratio']:.3f}")


if __name__ == "__main__":
    main()
