"""Degrade gracefully when ``hypothesis`` is absent (offline containers).

Property-based tests import ``given``/``settings``/``st`` from here instead
of from ``hypothesis`` directly.  When the real library is installed it is
re-exported unchanged; otherwise a minimal deterministic fallback runs each
property on ``max_examples`` seeded pseudo-random draws -- weaker than real
shrinking/coverage, but the invariants still get exercised in CI images
without the dependency.

Only the strategy surface this repo uses is implemented: ``st.integers``,
``st.booleans``, ``st.lists`` and ``st.composite``.
"""
from __future__ import annotations

try:                                      # pragma: no cover - env dependent
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover - env dependent
    import functools
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A draw(rng) closure masquerading as a hypothesis strategy."""

        def __init__(self, draw_fn):
            self._draw = draw_fn

        def draw(self, rng: random.Random):
            return self._draw(rng)

    class _St:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def lists(elements: _Strategy, min_size: int = 0,
                  max_size: int = 10) -> _Strategy:
            return _Strategy(lambda rng: [
                elements.draw(rng)
                for _ in range(rng.randint(min_size, max_size))])

        @staticmethod
        def composite(fn):
            """fn(draw, *args) -> value; returns a strategy factory."""
            def factory(*args, **kwargs):
                def draw_fn(rng: random.Random):
                    return fn(lambda strat: strat.draw(rng), *args, **kwargs)
                return _Strategy(draw_fn)
            return factory

    st = _St()

    _MAX_EXAMPLES = 20

    def settings(max_examples: int = _MAX_EXAMPLES, **_ignored):
        """Records max_examples for the @given below it (deadline etc. are
        accepted and ignored)."""
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(*strategies: _Strategy):
        def deco(fn):
            import inspect

            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_compat_max_examples",
                            getattr(fn, "_compat_max_examples",
                                    _MAX_EXAMPLES))
                rng = random.Random(1234)
                for _ in range(n):
                    drawn = tuple(s.draw(rng) for s in strategies)
                    fn(*args, *drawn, **kwargs)
            # strategies fill the trailing parameters; hide them from pytest
            # so it does not look for fixtures with those names
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            wrapper.__signature__ = sig.replace(
                parameters=params[: len(params) - len(strategies)])
            functools.update_wrapper(wrapper, fn,
                                     assigned=("__name__", "__doc__",
                                               "__module__", "__qualname__"))
            return wrapper
        return deco
