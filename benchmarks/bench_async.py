"""Server-aggregator sweep: simulated wall-clock vs accuracy per mode.

The sync servers ("mean", "diloco") wait for the slowest uplink of every
window, so their simulated wall-clock (History.server_wall_s, f64) is the
max over syncing devices of comm + compute time; "semi_sync" waits at most
the scenario-derived deadline and folds late updates staleness-weighted
into later rounds (docs/ARCHITECTURE.md §11).  This bench runs all three
:data:`repro.core.server.AGGREGATORS` under the profiles where the sync
barrier actually hurts --

* ``static``            -- the control: everyone on time, all modes tie
* ``gilbert_flaky``     -- burst outages + flaky devices (registry entry)
* ``stragglers``        -- every 4th device computes 3x slower
* ``flaky_stragglers``  -- both at once (the acceptance profile:
                           "gilbert_flaky + stragglers")

-- and records per (profile, aggregator) the simulated wall, final
accuracy/loss, and the resource spends.  Rows land in ``BENCH_async.json``
via ``benchmarks/run.py`` (the bench-smoke CI lane uploads it);
``benchmarks/check_regression.py --async-current`` gates the headline
claim: under the straggler profiles some async aggregator must beat the
sync server's wall-clock at <= 2 points of accuracy loss.

The straggler profiles are defined here rather than in the SCENARIOS
registry: registering them would enroll them in every parametrized
scenario-zoo test, and they exist to exercise the *server*, not the
channel chains.
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core import FLConfig, run_baseline
from repro.core.scenario import (SCENARIOS, DropoutSpec, GilbertElliottSpec,
                                 Scenario, StragglerSpec)

from .common import emit

_STRAG = StragglerSpec(slow_every=4, slowdown=3.0)

# bench-local profiles (see module docstring for why they are not registry
# entries); gilbert_flaky comes straight from the registry
PROFILES = {
    "static": SCENARIOS["static"],
    "gilbert_flaky": SCENARIOS["gilbert_flaky"],
    "stragglers": Scenario(name="stragglers", straggler=_STRAG),
    "flaky_stragglers": Scenario(
        name="flaky_stragglers",
        gilbert_elliott=GilbertElliottSpec(p_gb=0.2, p_bg=0.3),
        dropout=DropoutSpec(base_prob=0.05, flaky_every=4, flaky_prob=0.3),
        straggler=_STRAG),
}

AGG_CFGS = {
    "mean": {},
    "diloco": {},
    "semi_sync": {"staleness_cap": 2},
}


def _row(profile: str, aggregator: str, hist, wall: float, m: int,
         rounds: int) -> dict:
    return {
        "profile": profile, "aggregator": aggregator, "m_devices": m,
        "rounds": rounds, "wall_s": round(wall, 3),
        "sim_wall_clock_s": round(hist.server_wall_s[-1], 4),
        "final_loss": round(hist.loss[-1], 4),
        "final_accuracy": round(hist.accuracy[-1], 4),
        "energy_j": round(hist.energy_j[-1], 2),
        "money": round(hist.money[-1], 4),
        "time_s": round(hist.time_s[-1], 2),
        "uplink_mb": round(hist.uplink_mb[-1], 4),
    }


def run(profiles=None, m: int = 8, rounds: int = 60, n_train: int = 1500,
        emit_csv: bool = True) -> dict:
    from repro.models.paper_models import make_mnist_task
    names = list(profiles or PROFILES)
    rows = []
    for name in names:
        scn = PROFILES[name]
        task = make_mnist_task("lr", m_devices=m, n_train=n_train,
                               scenario=scn)
        for agg, extra in AGG_CFGS.items():
            cfg = FLConfig(rounds=rounds, eval_every=max(rounds // 4, 1),
                           scenario=scn, aggregator=agg, **extra)
            t0 = time.time()
            h = run_baseline(task, cfg, "lgc", h=4, engine="batched")
            rows.append(_row(name, agg, h, time.time() - t0, m, rounds))
            if emit_csv:
                r = rows[-1]
                emit(f"async_{name}_{agg}", r["wall_s"] * 1e6 / rounds,
                     f"sim_wall={r['sim_wall_clock_s']};"
                     f"acc={r['final_accuracy']}")
    return {"m_devices": m, "rounds": rounds, "rows": rows}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--m", type=int, default=8)
    ap.add_argument("--profiles", default=None,
                    help="comma-separated profile names (default: all)")
    ap.add_argument("--out", default="BENCH_async.json")
    args = ap.parse_args()
    names = args.profiles.split(",") if args.profiles else None
    res = run(profiles=names, m=args.m, rounds=args.rounds)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
