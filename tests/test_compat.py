"""Tests for the launch/compat version shims.

``ensure_fast_cpu_runtime`` is the load-bearing PR-7 path: it decides,
from the jaxlib version and the process environment, whether the
``--xla_cpu_use_thunk_runtime=false`` flag is appended to ``XLA_FLAGS``
before backend init (docs/ARCHITECTURE.md §10).  A wrong decision is
either a 37x slowdown (flag missing on 0.4.3x) or a hard startup crash
(unknown flag on >= 0.5), so the version gate's *boundaries* are pinned
here with mocked jaxlib versions -- the function reads
``jaxlib.__version__`` at call time, which is what makes it mockable.
"""
from __future__ import annotations

import jaxlib
import pytest

from repro.launch.compat import (ensure_fast_cpu_runtime,
                                 force_host_device_count)

FLAG = "--xla_cpu_use_thunk_runtime=false"
COUNT8 = "--xla_force_host_platform_device_count=8"


@pytest.fixture
def clean_env(monkeypatch):
    """No XLA_FLAGS, no opt-out: the decision rests on the version gate."""
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    monkeypatch.delenv("REPRO_XLA_THUNK_RUNTIME", raising=False)
    return monkeypatch


class TestVersionGate:
    """The flag applies exactly on [0.4.32, 0.5.0) -- the jaxlib line that
    ships both runtimes.  Outside it the flag is unknown to XLA (hard
    startup error), so both boundaries matter."""

    @pytest.mark.parametrize("version,expected", [
        ("0.4.31", False),    # pre-thunk-runtime: nothing to opt out of
        ("0.4.32", True),     # first thunk-runtime release
        ("0.4.37", True),     # the pinned CI container
        ("0.4.38.dev20250101", True),   # dev builds parse by numeric prefix
        ("0.5.0", False),     # legacy runtime removed; flag now fatal
        ("0.6.1", False),
    ])
    def test_boundary(self, clean_env, version, expected):
        clean_env.setattr(jaxlib, "__version__", version)
        import os
        assert ensure_fast_cpu_runtime() is expected
        assert (FLAG in os.environ.get("XLA_FLAGS", "")) is expected

    def test_unparseable_version_is_a_noop(self, clean_env):
        clean_env.setattr(jaxlib, "__version__", "weekly-nightly")
        import os
        assert ensure_fast_cpu_runtime() is False
        assert "XLA_FLAGS" not in os.environ


class TestOptOut:
    def test_env_opt_out_wins_over_version(self, clean_env):
        clean_env.setattr(jaxlib, "__version__", "0.4.37")
        clean_env.setenv("REPRO_XLA_THUNK_RUNTIME", "1")
        import os
        assert ensure_fast_cpu_runtime() is False
        assert "XLA_FLAGS" not in os.environ

    def test_opt_out_only_honours_exactly_1(self, clean_env):
        clean_env.setattr(jaxlib, "__version__", "0.4.37")
        clean_env.setenv("REPRO_XLA_THUNK_RUNTIME", "0")
        assert ensure_fast_cpu_runtime() is True


class TestIdempotence:
    def test_second_call_does_not_duplicate_the_flag(self, clean_env):
        clean_env.setattr(jaxlib, "__version__", "0.4.35")
        import os
        assert ensure_fast_cpu_runtime() is True
        flags_after_first = os.environ["XLA_FLAGS"]
        assert ensure_fast_cpu_runtime() is True
        assert os.environ["XLA_FLAGS"] == flags_after_first
        assert flags_after_first.count(FLAG) == 1

    def test_flag_already_present_short_circuits_any_version(self, clean_env):
        # a caller (or CI lane) that already set the flag wins outright,
        # even on a jaxlib where the gate itself would say no
        clean_env.setattr(jaxlib, "__version__", "0.5.0")
        clean_env.setenv("XLA_FLAGS", f"--some_other_flag {FLAG}")
        import os
        before = os.environ["XLA_FLAGS"]
        assert ensure_fast_cpu_runtime() is True
        assert os.environ["XLA_FLAGS"] == before

    def test_existing_xla_flags_content_is_preserved(self, clean_env):
        clean_env.setattr(jaxlib, "__version__", "0.4.33")
        clean_env.setenv(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
        import os
        assert ensure_fast_cpu_runtime() is True
        flags = os.environ["XLA_FLAGS"].split()
        assert "--xla_force_host_platform_device_count=8" in flags
        assert FLAG in flags


class TestForceHostDeviceCountComposition:
    """The two env mutators must compose in EITHER order.

    examples/train_100m_lgc.py used to do
    ``os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_...")``,
    which is a silent no-op whenever XLA_FLAGS is inherited (a CI lane or a
    parent process that already ran ``ensure_fast_cpu_runtime``) -- the
    8-device mesh build then fails with "Number of devices 1 must be >= 8".
    These pins make that regression impossible to reintroduce quietly.
    """

    def test_force_after_ensure_keeps_runtime_flag(self, clean_env):
        # the exact bit-rot scenario: runtime flag already in the env
        clean_env.setattr(jaxlib, "__version__", "0.4.37")
        import os
        assert ensure_fast_cpu_runtime() is True
        force_host_device_count(8)
        flags = os.environ["XLA_FLAGS"].split()
        assert COUNT8 in flags and FLAG in flags
        assert flags.count(FLAG) == 1

    def test_ensure_after_force_keeps_device_count(self, clean_env):
        clean_env.setattr(jaxlib, "__version__", "0.4.37")
        import os
        force_host_device_count(8)
        assert ensure_fast_cpu_runtime() is True
        flags = os.environ["XLA_FLAGS"].split()
        assert COUNT8 in flags and FLAG in flags
        assert flags.count(COUNT8) == 1

    def test_inherited_count_is_replaced_not_shadowed(self, clean_env):
        """XLA honours the LAST occurrence of the flag; stale inherited
        values must be dropped, not merely appended after."""
        clean_env.setattr(jaxlib, "__version__", "0.4.37")
        import os
        clean_env.setenv(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=2")
        force_host_device_count(8)
        flags = os.environ["XLA_FLAGS"].split()
        assert COUNT8 in flags
        assert "--xla_force_host_platform_device_count=2" not in flags

    def test_idempotent(self, clean_env):
        clean_env.setattr(jaxlib, "__version__", "0.4.37")
        import os
        force_host_device_count(8)
        first = os.environ["XLA_FLAGS"]
        force_host_device_count(8)
        # flag ORDER may change (count is re-appended last, which XLA
        # honours); the set of flags must not
        assert set(os.environ["XLA_FLAGS"].split()) == set(first.split())
        assert os.environ["XLA_FLAGS"].split().count(COUNT8) == 1
