"""ArchConfig-driven model zoo: init / train forward / prefill / decode for
all six assigned architecture families (dense, moe, ssm, hybrid, vlm, audio).

Layer stacks are scanned (``jax.lax.scan`` over params stacked on a leading
L axis) so HLO size and compile time are O(1) in depth -- essential for the
512-device dry-runs of 60-layer models.  Heterogeneous stacks (zamba2's
shared attention block, whisper's encoder/decoder) are segmented scans.

Public entry points:
  init_params(cfg, key)                  -> params pytree
  lm_loss(params, cfg, batch)            -> scalar loss (train_4k)
  prefill(params, cfg, batch)            -> (logits_last, cache)
  decode_step(params, cfg, token, cache) -> (logits, cache)
  init_cache(cfg, batch_size, cache_len) -> zeroed cache pytree
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import moe as moe_lib
from . import ssd as ssd_lib
from .layers import (KVCache, apply_norm, apply_rope, attention_decode,
                     attention_train, attn_init, cache_update, mlp_forward,
                     mlp_init, norm_init, qkv_project, _expand_kv)

Array = jax.Array


# ===========================================================================
# init
# ===========================================================================

def _stacked(key: Array, n: int, fn):
    return jax.vmap(fn)(jax.random.split(key, n))


def _block_init(cfg: ArchConfig, key: Array, *, cross: bool = False) -> dict:
    ks = jax.random.split(key, 6)
    d, dt = cfg.d_model, cfg.dtype
    p = {
        "norm1": norm_init(d, cfg.norm, dt),
        "attn": attn_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                          cfg.qkv_bias, dt),
        "norm2": norm_init(d, cfg.norm, dt),
    }
    if cfg.arch_type == "moe":
        p["moe"] = moe_lib.moe_init(ks[1], d, cfg.n_experts, cfg.d_exp,
                                    cfg.mlp, dt)
    else:
        p["mlp"] = mlp_init(ks[1], d, cfg.d_ff, cfg.mlp, dt)
    if cross:
        p["norm_x"] = norm_init(d, cfg.norm, dt)
        p["cross"] = attn_init(ks[2], d, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                               False, dt)
    return p


def _ssm_block_init(cfg: ArchConfig, key: Array) -> dict:
    return {
        "norm1": norm_init(cfg.d_model, cfg.norm, cfg.dtype),
        "ssm": ssd_lib.ssm_init(key, cfg, cfg.dtype),
    }


def init_params(cfg: ArchConfig, key: Array) -> dict:
    ks = jax.random.split(key, 8)
    d, vp, dt = cfg.d_model, cfg.vocab_padded, cfg.dtype
    params: dict[str, Any] = {
        "embed": (jax.random.normal(ks[0], (vp, d)) * 0.02).astype(dt),
        "final_norm": norm_init(d, cfg.norm, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(ks[1], (d, vp)) * d ** -0.5
                             ).astype(dt)

    if cfg.arch_type in ("dense", "vlm", "moe"):
        params["blocks"] = _stacked(ks[2], cfg.n_layers,
                                    lambda k: _block_init(cfg, k))
    elif cfg.arch_type == "ssm":
        params["blocks"] = _stacked(ks[2], cfg.n_layers,
                                    lambda k: _ssm_block_init(cfg, k))
    elif cfg.arch_type == "hybrid":
        params["blocks"] = _stacked(ks[2], cfg.n_layers,
                                    lambda k: _ssm_block_init(cfg, k))
        params["shared"] = _block_init(cfg, ks[3])        # zamba2 shared block
    elif cfg.arch_type == "audio":
        params["enc_blocks"] = _stacked(
            ks[2], cfg.encoder_layers, lambda k: _block_init(cfg, k))
        params["blocks"] = _stacked(
            ks[3], cfg.n_layers, lambda k: _block_init(cfg, k, cross=True))
        params["enc_norm"] = norm_init(d, cfg.norm, dt)
        params["enc_pos"] = (jax.random.normal(ks[4], (cfg.encoder_seq, d))
                             * 0.01).astype(dt)
        params["dec_pos"] = (jax.random.normal(ks[5], (cfg.max_position, d))
                             * 0.01).astype(dt)
    else:
        raise ValueError(cfg.arch_type)

    if cfg.arch_type == "vlm":
        # projector from the (stub) vision encoder width to d_model
        params["vis_proj"] = (jax.random.normal(ks[6], (1024, d))
                              * 1024 ** -0.5).astype(dt)
    return params


# ===========================================================================
# train-mode blocks
# ===========================================================================

def _attn_block_train(x: Array, bp: dict, cfg: ArchConfig, positions: Array,
                      *, causal: bool = True, window: int = 0,
                      enc_out: Array | None = None) -> tuple[Array, Array]:
    """One attention+FFN block, full-sequence. Returns (x, aux_loss)."""
    h = apply_norm(x, bp["norm1"], cfg.norm)
    q, k, v = qkv_project(h, bp["attn"], cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                          cfg.qkv_bias)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    k = _expand_kv(k, cfg.n_heads)
    v = _expand_kv(v, cfg.n_heads)
    att = attention_train(q, k, v, causal=causal, window=window,
                          q_chunk=cfg.attn_q_chunk,
                          remat_chunks=cfg.attn_remat_chunks,
                          seq_shard=cfg.attn_seq_shard)
    x = x + att.reshape(*x.shape[:2], -1) @ bp["attn"]["wo"]

    if enc_out is not None:                                # whisper cross-attn
        h = apply_norm(x, bp["norm_x"], cfg.norm)
        q, _, _ = qkv_project(h, bp["cross"], cfg.n_heads, cfg.n_kv_heads,
                              cfg.hd, False)
        ek = _split_kv_from(enc_out, bp["cross"], cfg)
        att = attention_train(q, ek[0], ek[1], causal=False,
                              q_chunk=cfg.attn_q_chunk,
                              remat_chunks=cfg.attn_remat_chunks,
                              seq_shard=cfg.attn_seq_shard)
        x = x + att.reshape(*x.shape[:2], -1) @ bp["cross"]["wo"]

    h = apply_norm(x, bp["norm2"], cfg.norm)
    aux = jnp.float32(0.0)
    if cfg.arch_type == "moe":
        y, aux = moe_lib.moe_forward(
            h, bp["moe"], n_experts=cfg.n_experts,
            top_k=cfg.experts_per_tok,
            capacity_factor=cfg.moe_capacity_factor, mlp_kind=cfg.mlp)
    else:
        y = mlp_forward(h, bp["mlp"], cfg.mlp)
    return x + y, aux


def _split_kv_from(enc_out: Array, cross_p: dict, cfg: ArchConfig):
    k = enc_out @ cross_p["wk"]
    v = enc_out @ cross_p["wv"]
    b, s, _ = enc_out.shape
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.hd)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.hd)
    return _expand_kv(k, cfg.n_heads), _expand_kv(v, cfg.n_heads)


def _ssm_block_train(x: Array, bp: dict, cfg: ArchConfig) -> Array:
    h = apply_norm(x, bp["norm1"], cfg.norm)
    y, _ = ssd_lib.ssm_block(h, bp["ssm"], cfg)
    return x + y


# ===========================================================================
# train forward
# ===========================================================================

def _scan_blocks(x: Array, stacked: dict, fn, remat: bool):
    body = jax.checkpoint(fn) if remat else fn

    def step(carry, bp):
        x, aux = carry
        x, a = body(x, bp)
        return (x, aux + a), None
    (x, aux), _ = jax.lax.scan(step, (x, jnp.float32(0.0)), stacked)
    return x, aux


def forward_hidden(params: dict, cfg: ArchConfig, tokens: Array,
                   prefix: Array | None = None, *, window: int = 0
                   ) -> tuple[Array, Array, int]:
    """Full-sequence forward up to final norm.

    Returns (hidden (B, S_total, D), aux_loss, n_prefix) where the first
    n_prefix positions are modality-prefix positions (no LM loss).
    """
    x = params["embed"][tokens]                            # (B,S,D)
    n_prefix = 0
    if cfg.arch_type == "vlm":
        assert prefix is not None, "vlm needs patch embeddings"
        vis = prefix.astype(cfg.dtype) @ params["vis_proj"]
        x = jnp.concatenate([vis, x], 1)
        n_prefix = vis.shape[1]
    b, s, _ = x.shape
    positions = jnp.arange(s)

    if cfg.arch_type in ("dense", "vlm", "moe"):
        fn = lambda x, bp: _attn_block_train(x, bp, cfg, positions,
                                             window=window)
        x, aux = _scan_blocks(x, params["blocks"], fn, cfg.remat)
    elif cfg.arch_type == "ssm":
        fn = lambda x, bp: (_ssm_block_train(x, bp, cfg), jnp.float32(0.0))
        x, aux = _scan_blocks(x, params["blocks"], fn, cfg.remat)
    elif cfg.arch_type == "hybrid":
        x, aux = _hybrid_train(x, params, cfg, positions, window)
    elif cfg.arch_type == "audio":
        assert prefix is not None, "audio needs frame embeddings"
        enc = prefix.astype(cfg.dtype) + params["enc_pos"][None, :prefix.shape[1]]
        enc_fn = lambda x, bp: _attn_block_train(x, bp, cfg,
                                                 jnp.arange(enc.shape[1]),
                                                 causal=False)
        enc, _ = _scan_blocks(enc, params["enc_blocks"], enc_fn, cfg.remat)
        enc = apply_norm(enc, params["enc_norm"], cfg.norm)
        pos_ids = jnp.minimum(positions, cfg.max_position - 1)
        x = x + params["dec_pos"][pos_ids][None]
        dec_fn = lambda x, bp: _attn_block_train(x, bp, cfg, positions,
                                                 enc_out=enc, window=window)
        x, aux = _scan_blocks(x, params["blocks"], dec_fn, cfg.remat)
    else:
        raise ValueError(cfg.arch_type)

    return apply_norm(x, params["final_norm"], cfg.norm), aux, n_prefix


def _hybrid_train(x: Array, params: dict, cfg: ArchConfig, positions: Array,
                  window: int) -> tuple[Array, Array]:
    """zamba2: segments of mamba blocks, shared attn block between segments."""
    period = cfg.attn_every or cfg.n_layers
    aux = jnp.float32(0.0)
    fn = lambda x, bp: (_ssm_block_train(x, bp, cfg), jnp.float32(0.0))
    for seg_start in range(0, cfg.n_layers, period):
        x, _ = _attn_block_train(x, params["shared"], cfg, positions,
                                 window=window)
        seg_end = min(seg_start + period, cfg.n_layers)
        seg = jax.tree_util.tree_map(lambda a: a[seg_start:seg_end],
                                     params["blocks"])
        x, _ = _scan_blocks(x, seg, fn, cfg.remat)
    return x, aux


def logits_fn(params: dict, cfg: ArchConfig, hidden: Array) -> Array:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (hidden @ head).astype(jnp.float32)
    from .layers import maybe_constrain
    return maybe_constrain(logits, *([None] * (logits.ndim - 1)), "model")


def lm_loss(params: dict, cfg: ArchConfig, batch: dict) -> Array:
    """batch: tokens (B,S), labels (B,S), optional prefix (B,P,Dv|D).

    Cross-entropy is computed in sequence chunks (cfg.loss_chunk) with the
    chunk logits sharded over the model axis (vocab-parallel) and the chunk
    body rematerialised -- the (B,S,V) logits tensor never exists in HBM.
    """
    hidden, aux, n_prefix = forward_hidden(params, cfg, batch["tokens"],
                                           batch.get("prefix"))
    hidden = hidden[:, n_prefix:]
    labels = batch["labels"]
    b, s, d = hidden.shape
    chunk = min(cfg.loss_chunk, s)

    def chunk_nll(h_c, y_c):
        logits = logits_fn(params, cfg, h_c)               # (B,c,V) f32
        logz = jax.scipy.special.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, y_c[..., None], -1)[..., 0]
        return jnp.sum(logz - gold)

    if s % chunk != 0 or s == chunk:
        total = jax.checkpoint(chunk_nll)(hidden, labels) if cfg.remat \
            else chunk_nll(hidden, labels)
    else:
        n_chunks = s // chunk
        hs = jnp.swapaxes(hidden.reshape(b, n_chunks, chunk, d), 0, 1)
        ys = jnp.swapaxes(labels.reshape(b, n_chunks, chunk), 0, 1)
        body = jax.checkpoint(chunk_nll) if cfg.remat else chunk_nll

        def acc(tot, hy):
            return tot + body(*hy), None
        total, _ = jax.lax.scan(acc, jnp.float32(0.0), (hs, ys))

    loss = total / (b * s)
    return loss + cfg.router_aux_weight * aux / max(cfg.n_layers, 1)


# ===========================================================================
# caches
# ===========================================================================

def init_cache(cfg: ArchConfig, batch: int, cache_len: int) -> dict:
    """Zeroed decode cache sized for ``cache_len`` positions."""
    dt = cfg.dtype
    cache: dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    l, kv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd

    def kv_cache(n_l, length):
        return {"k": jnp.zeros((n_l, batch, kv, length, hd), dt),
                "v": jnp.zeros((n_l, batch, kv, length, hd), dt)}

    if cfg.arch_type in ("dense", "vlm", "moe"):
        cache["kv"] = kv_cache(l, cache_len)
    elif cfg.arch_type == "ssm":
        cache["ssm"] = _ssm_cache(cfg, l, batch)
    elif cfg.arch_type == "hybrid":
        cache["ssm"] = _ssm_cache(cfg, l, batch)
        n_sites = -(-cfg.n_layers // (cfg.attn_every or cfg.n_layers))
        cache["kv"] = kv_cache(n_sites, cache_len)
    elif cfg.arch_type == "audio":
        cache["kv"] = kv_cache(l, cache_len)
        cache["cross_k"] = jnp.zeros((l, batch, cfg.n_heads, cfg.encoder_seq,
                                      hd), dt)
        cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
    return cache


def _ssm_cache(cfg: ArchConfig, l: int, batch: int) -> dict:
    c = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": jnp.zeros((l, batch, cfg.ssm_conv - 1, c), cfg.dtype),
        "state": jnp.zeros((l, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                            cfg.ssm_state), jnp.float32),
    }


# ===========================================================================
# decode
# ===========================================================================

def _attn_block_decode(x: Array, bp: dict, cfg: ArchConfig, k_l: Array,
                       v_l: Array, pos: Array, length: Array, window: int,
                       cross_kv: tuple[Array, Array] | None = None):
    """x: (B,1,D). Returns (x, k_l, v_l) with the cache slot updated."""
    b = x.shape[0]
    h = apply_norm(x, bp["norm1"], cfg.norm)
    q, k, v = qkv_project(h, bp["attn"], cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                          cfg.qkv_bias)
    if cfg.use_rope:
        pvec = jnp.full((b, 1), pos, jnp.int32)
        q = apply_rope(q, pvec, cfg.rope_theta)
        k = apply_rope(k, pvec, cfg.rope_theta)
    cache = KVCache(k_l, v_l, length)
    cache = cache_update(cache, k, v, jnp.full((b,), pos, jnp.int32), window)
    att = attention_decode(q, cache, cfg.n_heads)
    x = x + att.reshape(b, 1, -1) @ bp["attn"]["wo"]

    if cross_kv is not None:
        h = apply_norm(x, bp["norm_x"], cfg.norm)
        q, _, _ = qkv_project(h, bp["cross"], cfg.n_heads, cfg.n_kv_heads,
                              cfg.hd, False)
        ck, cv = cross_kv
        xcache = KVCache(ck, cv, jnp.full((b,), ck.shape[2], jnp.int32))
        att = attention_decode(q, xcache, cfg.n_heads)
        x = x + att.reshape(b, 1, -1) @ bp["cross"]["wo"]

    h = apply_norm(x, bp["norm2"], cfg.norm)
    if cfg.arch_type == "moe":
        y, _ = moe_lib.moe_forward(
            h, bp["moe"], n_experts=cfg.n_experts,
            top_k=cfg.experts_per_tok,
            capacity_factor=cfg.moe_capacity_factor, mlp_kind=cfg.mlp)
    else:
        y = mlp_forward(h, bp["mlp"], cfg.mlp)
    return x + y, cache.k, cache.v


def decode_step(params: dict, cfg: ArchConfig, token: Array, cache: dict,
                *, window: int = 0) -> tuple[Array, dict]:
    """One serving step: token (B,1) int32 -> (logits (B,Vp), new cache)."""
    x = params["embed"][token]                            # (B,1,D)
    pos = cache["pos"]
    b = token.shape[0]
    cache_len = None
    if "kv" in cache:
        cache_len = cache["kv"]["k"].shape[3]
        length = jnp.minimum(jnp.full((b,), pos, jnp.int32), cache_len)

    if cfg.arch_type in ("dense", "vlm", "moe"):
        def step(x, xs):
            bp, k_l, v_l = xs
            x, k_n, v_n = _attn_block_decode(x, bp, cfg, k_l, v_l, pos,
                                             length, window)
            return x, (k_n, v_n)
        x, (k_new, v_new) = jax.lax.scan(
            step, x, (params["blocks"], cache["kv"]["k"], cache["kv"]["v"]))
        cache = dict(cache, kv={"k": k_new, "v": v_new})

    elif cfg.arch_type == "ssm":
        def step(x, xs):
            bp, conv_l, state_l = xs
            h = apply_norm(x, bp["norm1"], cfg.norm)
            y, conv_n, state_n = ssd_lib.ssm_block_step(h, bp["ssm"], cfg,
                                                        conv_l, state_l)
            return x + y, (conv_n, state_n)
        x, (conv_new, state_new) = jax.lax.scan(
            step, x, (params["blocks"], cache["ssm"]["conv"],
                      cache["ssm"]["state"]))
        cache = dict(cache, ssm={"conv": conv_new, "state": state_new})

    elif cfg.arch_type == "hybrid":
        x, cache = _hybrid_decode(params, cfg, x, cache, pos, window)

    elif cfg.arch_type == "audio":
        def step(x, xs):
            bp, k_l, v_l, ck, cv = xs
            x, k_n, v_n = _attn_block_decode(x, bp, cfg, k_l, v_l, pos,
                                             length, window,
                                             cross_kv=(ck, cv))
            return x, (k_n, v_n)
        pos_id = jnp.minimum(pos, cfg.max_position - 1)
        x = x + params["dec_pos"][pos_id][None, None]
        x, (k_new, v_new) = jax.lax.scan(
            step, x, (params["blocks"], cache["kv"]["k"], cache["kv"]["v"],
                      cache["cross_k"], cache["cross_v"]))
        cache = dict(cache, kv={"k": k_new, "v": v_new})
    else:
        raise ValueError(cfg.arch_type)

    x = apply_norm(x, params["final_norm"], cfg.norm)
    logits = logits_fn(params, cfg, x)[:, 0]
    cache["pos"] = pos + 1
    return logits, cache


def _hybrid_decode(params, cfg, x, cache, pos, window):
    period = cfg.attn_every or cfg.n_layers
    b = x.shape[0]
    cache_len = cache["kv"]["k"].shape[3]
    length = jnp.minimum(jnp.full((b,), pos, jnp.int32), cache_len)
    k_all, v_all = cache["kv"]["k"], cache["kv"]["v"]
    new_k, new_v = [], []
    conv_all, state_all = cache["ssm"]["conv"], cache["ssm"]["state"]
    new_conv, new_state = [], []

    def ssm_step(x, xs):
        bp, conv_l, state_l = xs
        h = apply_norm(x, bp["norm1"], cfg.norm)
        y, conv_n, state_n = ssd_lib.ssm_block_step(h, bp["ssm"], cfg,
                                                    conv_l, state_l)
        return x + y, (conv_n, state_n)

    for site, seg_start in enumerate(range(0, cfg.n_layers, period)):
        x, k_n, v_n = _attn_block_decode(x, params["shared"], cfg,
                                         k_all[site], v_all[site], pos,
                                         length, window)
        new_k.append(k_n)
        new_v.append(v_n)
        seg_end = min(seg_start + period, cfg.n_layers)
        sl = slice(seg_start, seg_end)
        seg = jax.tree_util.tree_map(lambda a: a[sl], params["blocks"])
        x, (conv_n, state_n) = jax.lax.scan(
            ssm_step, x, (seg, conv_all[sl], state_all[sl]))
        new_conv.append(conv_n)
        new_state.append(state_n)

    cache = dict(cache,
                 kv={"k": jnp.stack(new_k), "v": jnp.stack(new_v)},
                 ssm={"conv": jnp.concatenate(new_conv),
                      "state": jnp.concatenate(new_state)})
    return x, cache


# ===========================================================================
# prefill
# ===========================================================================

def prefill(params: dict, cfg: ArchConfig, batch: dict,
            cache_len: int | None = None) -> tuple[Array, dict]:
    """Process a full prompt; return (last-position logits, decode cache).

    For attention archs the cache holds the prompt K/V; for SSM/hybrid it
    holds conv tails + final recurrent states.  Prefill of the *cache* for
    scanned stacks would need per-layer K/V outputs; we run the block scan
    with K/V collected as scan outputs.
    """
    tokens = batch["tokens"]
    prefix = batch.get("prefix")
    b, s = tokens.shape
    x = params["embed"][tokens]
    n_prefix = 0
    if cfg.arch_type == "vlm":
        vis = prefix.astype(cfg.dtype) @ params["vis_proj"]
        x = jnp.concatenate([vis, x], 1)
        n_prefix = vis.shape[1]
    s_tot = x.shape[1]
    positions = jnp.arange(s_tot)

    if cfg.arch_type in ("dense", "vlm", "moe", "audio"):
        enc = None
        if cfg.arch_type == "audio":
            enc = prefix.astype(cfg.dtype) + params["enc_pos"][None, :prefix.shape[1]]
            enc_fn = lambda x, bp: _attn_block_train(
                x, bp, cfg, jnp.arange(enc.shape[1]), causal=False)
            enc, _ = _scan_blocks(enc, params["enc_blocks"], enc_fn, cfg.remat)
            enc = apply_norm(enc, params["enc_norm"], cfg.norm)
            pos_ids = jnp.minimum(positions, cfg.max_position - 1)
            x = x + params["dec_pos"][pos_ids][None]

        def step(x, bp):
            h = apply_norm(x, bp["norm1"], cfg.norm)
            q, k, v = qkv_project(h, bp["attn"], cfg.n_heads, cfg.n_kv_heads,
                                  cfg.hd, cfg.qkv_bias)
            if cfg.use_rope:
                q = apply_rope(q, positions, cfg.rope_theta)
                k = apply_rope(k, positions, cfg.rope_theta)
            ke = _expand_kv(k, cfg.n_heads)
            ve = _expand_kv(v, cfg.n_heads)
            att = attention_train(q, ke, ve, causal=True,
                                  q_chunk=cfg.attn_q_chunk,
                                  remat_chunks=cfg.attn_remat_chunks,
                                  seq_shard=cfg.attn_seq_shard)
            x = x + att.reshape(b, s_tot, -1) @ bp["attn"]["wo"]
            if enc is not None:
                hx = apply_norm(x, bp["norm_x"], cfg.norm)
                qx, _, _ = qkv_project(hx, bp["cross"], cfg.n_heads,
                                       cfg.n_kv_heads, cfg.hd, False)
                ck, cv = _split_kv_from(enc, bp["cross"], cfg)
                att = attention_train(qx, ck, cv, causal=False,
                                      q_chunk=cfg.attn_q_chunk,
                                      remat_chunks=cfg.attn_remat_chunks,
                                      seq_shard=cfg.attn_seq_shard)
                x = x + att.reshape(b, s_tot, -1) @ bp["cross"]["wo"]
            h = apply_norm(x, bp["norm2"], cfg.norm)
            if cfg.arch_type == "moe":
                y, _ = moe_lib.moe_forward(
                    h, bp["moe"], n_experts=cfg.n_experts,
                    top_k=cfg.experts_per_tok,
                    capacity_factor=cfg.moe_capacity_factor, mlp_kind=cfg.mlp)
            else:
                y = mlp_forward(h, bp["mlp"], cfg.mlp)
            kv_out = (jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2))
            if enc is not None:
                ck, cv = _split_kv_from(enc, bp["cross"], cfg)
                kv_out += (jnp.swapaxes(ck, 1, 2), jnp.swapaxes(cv, 1, 2))
            return x + y, kv_out

        body = jax.checkpoint(step) if cfg.remat else step
        x, kvs = jax.lax.scan(lambda c, bp: body(c, bp), x, params["blocks"])
        k_c, v_c = kvs[0], kvs[1]
        if cache_len is not None and cache_len > s_tot:
            pad = ((0, 0), (0, 0), (0, 0), (0, cache_len - s_tot), (0, 0))
            k_c, v_c = jnp.pad(k_c, pad), jnp.pad(v_c, pad)
        cache = {"pos": jnp.int32(s_tot), "kv": {"k": k_c, "v": v_c}}
        if cfg.arch_type == "audio":
            cache["cross_k"], cache["cross_v"] = kvs[2], kvs[3]

    elif cfg.arch_type in ("ssm", "hybrid"):
        cache = _prefill_ssm(params, cfg, x, positions, cache_len)
    else:
        raise ValueError(cfg.arch_type)

    x = apply_norm(x[:, -1:], params["final_norm"], cfg.norm)
    logits = logits_fn(params, cfg, x)[:, 0]
    return logits, cache


def _prefill_ssm(params, cfg, x, positions, cache_len=None):
    """SSM/hybrid prefill: collect conv tails + final states per layer."""
    kconv = cfg.ssm_conv - 1

    def step(x, bp):
        h = apply_norm(x, bp["norm1"], cfg.norm)
        zxbcdt = h @ bp["ssm"]["in_proj"]
        z, xbc, dt = ssd_lib._split_in_proj(zxbcdt, cfg)
        conv_tail = xbc[:, -kconv:, :]
        xbc_c = ssd_lib._causal_conv(xbc, bp["ssm"]["conv_w"],
                                     bp["ssm"]["conv_b"])
        di, ns = cfg.d_inner, cfg.ssm_state
        xs_in = xbc_c[..., :di]
        b_mat = xbc_c[..., di:di + ns].astype(jnp.float32)
        c_mat = xbc_c[..., di + ns:].astype(jnp.float32)
        dtp = jax.nn.softplus(dt.astype(jnp.float32) + bp["ssm"]["dt_bias"])
        bsz, s, _ = x.shape
        xh = xs_in.reshape(bsz, s, cfg.ssm_heads, cfg.ssm_head_dim)
        y, h_final = ssd_lib.ssd_chunked(xh, dtp, bp["ssm"]["a_log"], b_mat,
                                         c_mat, cfg.ssm_chunk)
        y = y + xh.astype(y.dtype) * bp["ssm"]["d_skip"][None, None, :, None
                                                         ].astype(y.dtype)
        y = y.reshape(bsz, s, di)
        y = ssd_lib.rmsnorm(y, bp["ssm"]["norm_scale"]) * jax.nn.silu(z)
        return x + y @ bp["ssm"]["out_proj"], (conv_tail, h_final)

    if cfg.arch_type == "ssm":
        body = jax.checkpoint(step) if cfg.remat else step
        x, (convs, states) = jax.lax.scan(body, x, params["blocks"])
        return {"pos": jnp.int32(x.shape[1]),
                "ssm": {"conv": convs, "state": states}}

    # hybrid: segments with the shared attention block between them
    period = cfg.attn_every or cfg.n_layers
    convs, states, ks, vs = [], [], [], []
    for seg_start in range(0, cfg.n_layers, period):
        h = apply_norm(x, params["shared"]["norm1"], cfg.norm)
        q, k, v = qkv_project(h, params["shared"]["attn"], cfg.n_heads,
                              cfg.n_kv_heads, cfg.hd, cfg.qkv_bias)
        if cfg.use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        att = attention_train(q, _expand_kv(k, cfg.n_heads),
                              _expand_kv(v, cfg.n_heads), causal=True,
                              q_chunk=cfg.attn_q_chunk,
                              remat_chunks=cfg.attn_remat_chunks,
                              seq_shard=cfg.attn_seq_shard)
        x = x + att.reshape(*x.shape[:2], -1) @ params["shared"]["attn"]["wo"]
        h = apply_norm(x, params["shared"]["norm2"], cfg.norm)
        x = x + mlp_forward(h, params["shared"]["mlp"], cfg.mlp)
        k_c, v_c = jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2)
        if cache_len is not None and cache_len > x.shape[1]:
            pad = ((0, 0), (0, 0), (0, cache_len - x.shape[1]), (0, 0))
            k_c, v_c = jnp.pad(k_c, pad), jnp.pad(v_c, pad)
        ks.append(k_c)
        vs.append(v_c)
        seg_end = min(seg_start + period, cfg.n_layers)
        seg = jax.tree_util.tree_map(lambda a: a[seg_start:seg_end],
                                     params["blocks"])
        x, (cv, st) = jax.lax.scan(step, x, seg)
        convs.append(cv)
        states.append(st)
    return {"pos": jnp.int32(x.shape[1]),
            "ssm": {"conv": jnp.concatenate(convs),
                    "state": jnp.concatenate(states)},
            "kv": {"k": jnp.stack(ks), "v": jnp.stack(vs)}}
