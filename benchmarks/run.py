"""Benchmark orchestrator: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (harness contract) and writes
the simulator-scaling rows to ``BENCH_sim.json`` (machine-readable, suitable
for CI artifact upload -- see .github/workflows/ci.yml).

``--smoke`` runs a minutes-scale subset (used by the CI benchmark job);
the default budgets match the curves in EXPERIMENTS.md.  Each bench_*
module also has a __main__ with --rounds/--out for full sweeps.

Every benchmark runs through :func:`_step`, which prints the per-benchmark
wall time to stderr and, on failure, exits naming the failing benchmark --
so a red bench-smoke CI lane is diagnosable from the last log line instead
of a bare traceback.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback


def _step(name: str, fn, *args, **kwargs):
    """Run one benchmark, print its wall time, exit naming it on failure."""
    t0 = time.perf_counter()
    try:
        out = fn(*args, **kwargs)
    except BaseException as e:
        if isinstance(e, (KeyboardInterrupt, SystemExit)):
            raise
        traceback.print_exc()
        print(f"[bench] FAILED {name} after {time.perf_counter() - t0:.1f}s",
              file=sys.stderr)
        sys.exit(f"benchmark failed: {name}")
    print(f"[bench] {name}: {time.perf_counter() - t0:.1f}s",
          file=sys.stderr)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced budgets + small device counts (CI)")
    ap.add_argument("--sim-json", default="BENCH_sim.json",
                    help="path for the machine-readable scaling rows")
    ap.add_argument("--controller-json", default="BENCH_controller.json",
                    help="path for the controller fleet-vs-list rows")
    ap.add_argument("--sharded-json", default="BENCH_sharded.json",
                    help="path for the mesh-scaling rows (sharded engine)")
    ap.add_argument("--scenarios-json", default="BENCH_scenarios.json",
                    help="path for the scenario-zoo fixed-vs-DDPG rows")
    ap.add_argument("--tasks-json", default="BENCH_tasks.json",
                    help="path for the task-zoo throughput/accuracy rows")
    ap.add_argument("--population-json", default="BENCH_population.json",
                    help="path for the population EF-store rows")
    ap.add_argument("--async-json", default="BENCH_async.json",
                    help="path for the server-aggregator wall/accuracy rows")
    ap.add_argument("--hundredm-json", default="BENCH_100m.json",
                    help="path for the 100M-stack wire/throughput frontier")
    args = ap.parse_args()

    from benchmarks import (bench_100m, bench_async,
                            bench_compressor_throughput,
                            bench_controller_scaling,
                            bench_convergence_bound, bench_fig3_lr_mnist,
                            bench_fig5_drl, bench_fig6_rnn_shakespeare,
                            bench_population, bench_scenarios,
                            bench_sharded_scaling, bench_sim_scaling,
                            bench_table1_channels, bench_tasks)

    _step("table1_channels", bench_table1_channels.run)          # Table 1
    _step("convergence_bound", bench_convergence_bound.run)      # Thm 1
    _step("compressor_throughput", bench_compressor_throughput.run,
          sizes=(65_536,))                                       # kernels
    if args.smoke:
        sim = _step("sim_scaling", bench_sim_scaling.run,
                    ms=(8, 16), rounds=24)                       # scaling
        ctrl = _step("controller_scaling", bench_controller_scaling.run,
                     ms=(8, 64))                                 # fleet DDPG
        sharded = _step("sharded_scaling", bench_sharded_scaling.run,
                        device_counts=(1, 8), m=256, rounds=24,
                        k_windows=15)                            # mesh scaling
        scen = _step("scenarios", bench_scenarios.run,
                     m=8, rounds=30, n_train=1500)               # scenario zoo
        tasks = _step("tasks", bench_tasks.run, m=8, rounds=24)  # task zoo
        popn = _step("population", bench_population.run,
                     n_devices=100_000, m_cohort=64, rounds=24)  # EF stores
        asynch = _step("async", bench_async.run,
                       m=8, rounds=60, n_train=1500)             # aggregators
        hundredm = _step("lgc_100m", bench_100m.run,
                         preset="smoke", m_devices=4, rounds=6)  # 100M stack
        _step("fig3_lr_mnist", bench_fig3_lr_mnist.run,
              model="lr", rounds=40, n_train=1200)
    else:
        sim = _step("sim_scaling", bench_sim_scaling.run,
                    ms=(8, 64, 256), rounds=200)
        ctrl = _step("controller_scaling", bench_controller_scaling.run,
                     ms=(8, 64, 256))
        sharded = _step("sharded_scaling", bench_sharded_scaling.run,
                        device_counts=(1, 2, 4, 8), m=256, rounds=40)
        scen = _step("scenarios", bench_scenarios.run,
                     m=16, rounds=120, n_train=4000)
        tasks = _step("tasks", bench_tasks.run, m=16, rounds=80)
        popn = _step("population", bench_population.run,
                     n_devices=100_000, m_cohort=64, rounds=80)
        asynch = _step("async", bench_async.run,
                       m=16, rounds=120, n_train=2000)
        hundredm = _step("lgc_100m", bench_100m.run,
                         preset="smoke", m_devices=8, rounds=12)
        _step("fig3_lr_mnist", bench_fig3_lr_mnist.run,
              model="lr", rounds=100, n_train=2000)              # Fig 3
        _step("fig4_cnn_mnist", bench_fig3_lr_mnist.run,
              model="cnn", rounds=40, n_train=1500)              # Fig 4
        _step("fig5_drl", bench_fig5_drl.run, rounds=120)        # Fig 5
        _step("fig6_rnn_shakespeare", bench_fig6_rnn_shakespeare.run,
              rounds=30)                                         # Fig 6

    with open(args.sim_json, "w") as f:
        json.dump(sim, f, indent=1)
    with open(args.controller_json, "w") as f:
        json.dump(ctrl, f, indent=1)
    with open(args.sharded_json, "w") as f:
        json.dump(sharded, f, indent=1)
    with open(args.scenarios_json, "w") as f:
        json.dump(scen, f, indent=1)
    with open(args.tasks_json, "w") as f:
        json.dump(tasks, f, indent=1)
    with open(args.population_json, "w") as f:
        json.dump(popn, f, indent=1)
    with open(args.async_json, "w") as f:
        json.dump(asynch, f, indent=1)
    with open(args.hundredm_json, "w") as f:
        json.dump(hundredm, f, indent=1)


if __name__ == '__main__':
    main()
