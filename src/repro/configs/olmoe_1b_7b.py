"""OLMoE-1B-7B [arXiv:2409.02060] -- 64 experts, top-8, d_expert=1024."""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", arch_type="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab_size=50_304,
    n_experts=64, experts_per_tok=8, d_expert=1024,
    mlp="swiglu", norm="rmsnorm",
    source="arXiv:2409.02060",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="olmoe-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=128, d_expert=128, vocab_size=512,
        n_experts=4, experts_per_tok=2, remat=False, attn_q_chunk=64)
