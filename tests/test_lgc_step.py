"""make_lgc_train_step equivalence ladder (the 100M-stack engine rung).

Same discipline as tests/test_tasks.py, applied to the shard_map step the
qwen2_100m task drives: the sparse and bucket uplinks must reproduce the
dense server sum, at every mesh size the process can build ({1, 8} when
the test-sharded lane forces 8 host devices), under a static and a
gilbert_flaky multi-channel scenario.

At SATURATING sparsity -- cumulative channel budgets clamped to the leaf
size, i.e. every coordinate is transmitted -- dense_masked, sparse_gather,
bucket_sparse and the FedAvg baseline are the same algorithm, so their
trajectories must agree BIT-FOR-BIT on a 1-device mesh (no histogram-tie
or top_k-order escape hatches) and to reduction-order rounding on larger
meshes (the dense server sum is an XLA all-reduce; the sparse paths
accumulate gathered shards sequentially -- same addends, different order).  Non-saturating selection is pinned at the leaf level
with a distinct-bin magnitude construction where histogram selection is
provably exact.

Also here: the k-budget cumulative clamp (_leaf_ks) that used to let a
64-element bias at sparsity (0.01, 0.02, 0.02) request 3 coordinates, the
Pallas-vs-oracle backend parity, the delivery-mask freeze (nothing
delivered => params bit-frozen, error memory grows), and the per-device
stacked EF rows.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_smoke_config
from repro.kernels import ref as kref
from repro.launch import compat
from repro.launch.mesh import fl_axis_name, make_host_mesh
from repro.launch.steps import (_compress_leaf_bucket, _compress_leaf_dense,
                                _compress_leaf_sparse, _leaf_ks,
                                lgc_wire_bytes_per_round, LGCStepConfig)
from repro.models.lgc_transformer import make_qwen2_100m_task
from repro.models.paper_models import ENGINE_TASKS, TASKS, make_task

N_DEV = len(jax.devices())
MESHES = sorted({1, N_DEV})
SATURATING = (1.0, 0.5, 0.5)     # cum clamp => every coordinate transmitted

TINY = dataclasses.replace(
    get_smoke_config("qwen2-100m"), name="qwen2-tiny", n_layers=1,
    d_model=32, n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=64,
    attn_q_chunk=16, loss_chunk=16)

_RUNS: dict = {}


def _traj(mesh_n: int, aggregate: str, scenario=None,
          sparsity=SATURATING, backend="exact", rounds=4, **kw):
    """Cached (losses, final params, final ef) for one configuration."""
    key = (mesh_n, aggregate, scenario, sparsity, backend, rounds,
           tuple(sorted(kw.items())))
    if key not in _RUNS:
        t = make_qwen2_100m_task(m_devices=mesh_n, arch=TINY,
                                 aggregate=aggregate, sparsity=sparsity,
                                 scenario=scenario, local_steps=2, seq=16,
                                 backend=backend, **kw)
        out = t.run(rounds)
        _RUNS[key] = (out["losses"], jax.device_get(t._built["params"]),
                      jax.device_get(t._built["ef"]))
    return _RUNS[key]


def _assert_tree_bits_equal(a, b, msg=""):
    for (pa, la), (pb, lb) in zip(jax.tree_util.tree_leaves_with_path(a),
                                  jax.tree_util.tree_leaves_with_path(b)):
        xa, xb = np.asarray(la), np.asarray(lb)
        if xa.dtype == jnp.bfloat16:
            xa, xb = xa.view(np.uint16), xb.view(np.uint16)
        np.testing.assert_array_equal(xa, xb, err_msg=f"{msg}{pa}")


def _assert_tree_matches(a, b, mesh_n, msg=""):
    """Bitwise on a 1-device mesh.  On mesh > 1 the dense server sum is an
    XLA all-reduce while the sparse/bucket paths accumulate gathered shards
    sequentially -- same multiset of addends, different order -- so agreement
    is to reduction-order rounding (~1 ulp of the bf16 params)."""
    if mesh_n == 1:
        _assert_tree_bits_equal(a, b, msg)
        return
    for (pa, la), (pb, lb) in zip(jax.tree_util.tree_leaves_with_path(a),
                                  jax.tree_util.tree_leaves_with_path(b)):
        np.testing.assert_allclose(
            np.asarray(la, np.float32), np.asarray(lb, np.float32),
            atol=1e-3, rtol=1e-2, err_msg=f"{msg}{pa}")


def _assert_losses_match(l1, l2, mesh_n):
    if mesh_n == 1:
        assert l1 == l2
    else:
        np.testing.assert_allclose(l1, l2, atol=1e-4)


class TestSmallLeafBudgets:
    """The satellite bugfix: per-channel ks are cumulatively clamped."""

    def test_64_element_bias_keeps_channels_disjoint(self):
        # naive max(1, int(64*f)) would be [1, 1, 1] too -- but ONLY because
        # of the clamp discipline does the invariant below hold for it
        assert _leaf_ks(64, (0.01, 0.02, 0.02)) == [1, 1, 1]

    def test_two_element_leaf_overflow_channels_go_empty(self):
        # naive floors request 3 coords from a 2-element leaf
        assert _leaf_ks(2, (0.9, 0.9, 0.9)) == [1, 1, 0]

    def test_saturating_first_channel_takes_all(self):
        assert _leaf_ks(10, SATURATING) == [10, 0, 0]

    def test_cumulative_budget_never_exceeds_leaf(self):
        rng = np.random.default_rng(0)
        for _ in range(200):
            size = int(rng.integers(1, 500))
            c = int(rng.integers(1, 5))
            fr = tuple(float(f) for f in rng.uniform(0, 1.2, c))
            ks = _leaf_ks(size, fr)
            assert sum(ks) <= size
            assert all(k >= 0 for k in ks)
            assert ks[0] >= 1                      # at least one coordinate

    def test_wire_accounting_uses_clamped_budgets(self):
        params = {"w": jnp.zeros(64), "b": jnp.zeros(2)}
        cfg = LGCStepConfig(sparsity=(0.01, 0.02, 0.02))
        wire = lgc_wire_bytes_per_round(params, cfg)
        # 64-leaf: [1,1,1]; 2-leaf: [1,1,0]  => 5 coords * (4+4) bytes
        assert wire["sparse_gather"] == wire["bucket_sparse"] == 5 * 8
        assert wire["none"] == 66 * 4
        assert wire["dense_masked"] == 66 * 4      # f32 psum default


class TestUplinkEquivalence:
    """sparse/bucket uplinks == dense server sum, mesh {1, N_DEV}."""

    @pytest.mark.parametrize("mesh_n", MESHES)
    @pytest.mark.parametrize("aggregate", ["sparse_gather", "bucket_sparse",
                                           "none"])
    def test_static_saturating_matches_dense_bitwise(self, mesh_n, aggregate):
        """Everything transmitted => all four aggregates are the same
        algorithm; trajectories must agree to the last bit on a 1-device
        mesh (reduction-order rounding on larger ones)."""
        ref_l, ref_p, _ = _traj(mesh_n, "dense_masked")
        l, p, _ = _traj(mesh_n, aggregate)
        _assert_losses_match(l, ref_l, mesh_n)
        _assert_tree_matches(p, ref_p, mesh_n, f"{aggregate}@{mesh_n}: ")

    @pytest.mark.parametrize("mesh_n", MESHES)
    @pytest.mark.parametrize("aggregate", ["sparse_gather", "bucket_sparse"])
    def test_flaky_channel_masks_match_dense_bitwise(self, mesh_n, aggregate):
        """gilbert_flaky delivery masks thread identically through all
        compressed uplinks: undelivered mass stays in EF on every path."""
        ref = _traj(mesh_n, "dense_masked", scenario="gilbert_flaky",
                    sparsity=(1.0,))
        got = _traj(mesh_n, aggregate, scenario="gilbert_flaky",
                    sparsity=(1.0,))
        _assert_losses_match(got[0], ref[0], mesh_n)
        _assert_tree_matches(got[1], ref[1], mesh_n, f"{aggregate}@{mesh_n}: ")
        _assert_tree_matches(got[2], ref[2], mesh_n,
                             f"ef {aggregate}@{mesh_n}: ")

    @pytest.mark.parametrize("mesh_n", MESHES)
    def test_learns_with_real_compression(self, mesh_n):
        """Non-saturating sparse_gather at tiny scale still learns (mean of
        first 3 vs last 3 rounds -- single-round noise is real here)."""
        l, _, _ = _traj(mesh_n, "sparse_gather", sparsity=(0.05, 0.1, 0.1),
                        rounds=20, local_lr=5e-3)
        assert np.isfinite(l).all()
        assert np.mean(l[-3:]) < np.mean(l[:3])


def _run_leaf(fn, e, d, sparsity, recv, **kw):
    """Run one leaf compressor inside a 1-device shard_map (the sparse and
    bucket paths issue all_gathers, so they need a mapped axis)."""
    mesh = make_host_mesh(1)
    fl_ax = fl_axis_name(mesh)
    f = compat.shard_map(
        lambda e_, d_, r_: fn(e_, d_, sparsity, r_, fl_ax, 1, **kw),
        mesh=mesh, in_specs=(P(), P(), P()), out_specs=(P(), P()),
        axis_names={fl_ax})
    # partial-auto shard_map only lowers under jit on the pinned jax
    return jax.jit(f)(e, d, recv)


class TestLeafLevelSelection:
    """Non-saturating selection, pinned where it is provably exact: 64
    linear-spaced magnitudes occupy 64 distinct histogram bins, so the
    256-bin threshold rule selects EXACTLY the top cum-k ranks."""

    COLS = 64
    SP = (0.1, 0.2)          # ks = [6, 12] -> ranks 0-5 / 6-17

    def _u(self):
        # half-integer magnitudes: each lands strictly INSIDE its own
        # 256-bin histogram bucket, so no value ever sits on a threshold
        # edge (selection is strict >) and every rank cut is exact
        rng = np.random.default_rng(7)
        mag = np.arange(self.COLS, dtype=np.float32) + 1.5
        sign = np.where(rng.integers(0, 2, self.COLS), 1.0, -1.0)
        return jnp.asarray(rng.permutation(mag) * sign)

    def test_sparse_equals_dense_oracle(self):
        u = self._u()
        e, d = jnp.zeros_like(u), u
        recv = jnp.ones(2, jnp.int32)
        g_d, e_d = _compress_leaf_dense(e, d, self.SP, recv)
        g_s, e_s = _run_leaf(_compress_leaf_sparse, e, d, self.SP, recv)
        np.testing.assert_array_equal(np.asarray(g_d), np.asarray(g_s))
        np.testing.assert_array_equal(np.asarray(e_d), np.asarray(e_s))
        # and the selection is the exact top-18 by |u|
        assert int((g_d != 0).sum()) == 18
        kept = np.abs(np.asarray(u))[np.asarray(g_d) != 0]
        assert kept.min() == self.COLS - 18 + 1.5

    def test_masked_channel_stays_in_error_memory(self):
        """recv = (1, 0): channel 1's 12 coordinates are selected but not
        delivered -- g carries only channel 0, EF keeps the rest."""
        u = self._u()
        e, d = jnp.zeros_like(u), u
        recv = jnp.asarray([1, 0], jnp.int32)
        g_d, e_d = _compress_leaf_dense(e, d, self.SP, recv)
        g_s, e_s = _run_leaf(_compress_leaf_sparse, e, d, self.SP, recv)
        np.testing.assert_array_equal(np.asarray(g_d), np.asarray(g_s))
        np.testing.assert_array_equal(np.asarray(e_d), np.asarray(e_s))
        assert int((g_d != 0).sum()) == 6          # channel 0 only
        kept = np.abs(np.asarray(u))[np.asarray(g_d) != 0]
        assert kept.min() == self.COLS - 6 + 1.5

    @pytest.mark.parametrize("recv", [(1, 1), (1, 0), (0, 1), (0, 0)])
    def test_ef_conservation_all_paths(self, recv):
        """u = g_own + e_new exactly, on every path and every mask: mass is
        either on the wire or in the error memory, never dropped or doubled
        (the bucket path's seed version leaked the untransmitted tail)."""
        u = self._u()
        e = jnp.asarray(np.random.default_rng(3).normal(size=self.COLS)
                        .astype(np.float32))
        d = u
        r = jnp.asarray(recv, jnp.int32)
        tot = np.asarray(e + d)
        for name, (g, e_new) in {
            "dense": _compress_leaf_dense(e, d, self.SP, r),
            "sparse": _run_leaf(_compress_leaf_sparse, e, d, self.SP, r),
            "bucket": _run_leaf(_compress_leaf_bucket, e, d, self.SP, r),
        }.items():
            # n_fl=1: g_mean == g_own, so the identity is directly checkable
            np.testing.assert_allclose(np.asarray(g) + np.asarray(e_new),
                                       tot, atol=1e-6, err_msg=name)


class TestDeliveryMaskFreeze:
    def test_nothing_delivered_freezes_params_and_grows_ef(self):
        """received == 0 for every device and channel: the server sum is
        empty, params must not move by a single bit, and the residual mass
        keeps accumulating."""
        t = make_qwen2_100m_task(m_devices=1, arch=TINY, local_steps=2,
                                 seq=16, sparsity=(0.05, 0.1, 0.1))
        b = t.build()
        params, ef, step, pipe = b["params"], b["ef"], b["step"], b["pipe"]
        p0 = jax.device_get(params)                # donate-safe snapshot
        zeros = jnp.zeros((1, t.step_cfg.n_channels), jnp.int32)
        masses = []
        for _ in range(3):
            x, y = pipe.next_batch()
            params, ef, _ = step(params, ef, {"tokens": jnp.asarray(x),
                                              "labels": jnp.asarray(y)},
                                 zeros)
            masses.append(sum(float(jnp.sum(jnp.abs(e)))
                              for e in jax.tree_util.tree_leaves(ef)))
        _assert_tree_bits_equal(jax.device_get(params), p0)
        assert masses[0] > 0 and masses[2] > masses[1] > masses[0]


class TestPallasBackend:
    def test_pallas_backend_bitwise_matches_oracle(self):
        """backend="pallas" with the routing floor lowered to 1 sends every
        dense-path leaf through kernels.lgc_compress_hist; the trajectory
        must be bit-identical to the exact kref oracle."""
        ref = _traj(1, "dense_masked", sparsity=(0.05, 0.1, 0.1), rounds=3)
        got = _traj(1, "dense_masked", sparsity=(0.05, 0.1, 0.1), rounds=3,
                    backend="pallas", pallas_min_elems=1)
        assert got[0] == ref[0]
        _assert_tree_bits_equal(got[1], ref[1], "pallas params: ")
        _assert_tree_bits_equal(got[2], ref[2], "pallas ef: ")

    def test_routing_floor_keeps_small_leaves_on_oracle(self):
        """Default PALLAS_MIN_ELEMS is far above the tiny arch's leaves, so
        backend="pallas" at the default floor is the oracle path -- still
        bit-identical (the routing threshold itself changes nothing)."""
        ref = _traj(1, "dense_masked", sparsity=(0.05, 0.1, 0.1), rounds=3)
        got = _traj(1, "dense_masked", sparsity=(0.05, 0.1, 0.1), rounds=3,
                    backend="pallas")
        assert got[0] == ref[0]
        _assert_tree_bits_equal(got[1], ref[1])


class TestStackedErrorFeedback:
    def test_ef_leaves_are_stacked_per_device(self):
        _, _, ef = _traj(MESHES[-1], "sparse_gather",
                         sparsity=(0.05, 0.1, 0.1), rounds=4)
        for leaf in jax.tree_util.tree_leaves(ef):
            assert leaf.shape[0] == MESHES[-1]

    @pytest.mark.skipif(N_DEV < 2, reason="needs a multi-device mesh")
    def test_ef_rows_differ_across_devices(self):
        """Each FL device owns its own residual row.  The seed code's
        replicated P() spec collapsed device_get to shard 0's row -- with
        per-device data the rows MUST differ."""
        _, _, ef = _traj(N_DEV, "sparse_gather", sparsity=(0.05, 0.1, 0.1),
                         rounds=4)
        distinct = False
        for leaf in jax.tree_util.tree_leaves(ef):
            rows = np.asarray(leaf).reshape(N_DEV, -1)
            if not np.allclose(rows, rows[0:1]):
                distinct = True
        assert distinct


class TestRegistry100m:
    def test_qwen2_100m_is_registered(self):
        assert "qwen2_100m" in TASKS
        spec = TASKS["qwen2_100m"]
        assert spec.dataset == "tokens" and not spec.is_engine_task

    def test_engine_tasks_excludes_the_token_stack(self):
        assert set(ENGINE_TASKS) == {"lr_mnist", "cnn_mnist",
                                     "rnn_shakespeare"}
        assert "qwen2_100m" not in ENGINE_TASKS

    def test_make_task_smoke_builds(self):
        t = make_task("qwen2_100m", m_devices=1, preset="smoke")
        assert t.n_devices == 1
        assert t.param_count() > 100_000

    def test_full_preset_is_a_real_100m(self):
        """The tentpole number: >= 1e8 flattened gradient elements, every
        matmul leaf above the Pallas routing floor (eval_shape only -- no
        128M-param init in the test lane)."""
        from repro.core.compressor import PALLAS_MIN_ELEMS
        t = make_task("qwen2_100m", m_devices=8)
        assert t.param_count() >= 100_000_000
        assert t.step_cfg.backend == "pallas"
        assert t.step_cfg.pallas_min_elems == PALLAS_MIN_ELEMS
        d = t.arch.d_model
        assert d * d >= PALLAS_MIN_ELEMS // 8      # attn leaves route

    def test_wire_accounting_is_published(self):
        t = make_task("qwen2_100m", m_devices=8)
        dense = t.param_count() * 4
        sparse = t.wire_bytes_per_round()
        assert 0 < sparse < dense / 10             # >10x wire reduction
