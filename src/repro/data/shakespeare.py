"""Shakespeare character-LM data (paper Fig. 6, RNN task).

The container is offline, so we embed a public-domain excerpt (sonnets +
play fragments) and tile it with light stochastic re-ordering to reach the
requested corpus size.  Character-level vocabulary mirrors the LEAF /
FedML Shakespeare setup the paper uses.
"""
from __future__ import annotations

import numpy as np

_EXCERPT = """
Shall I compare thee to a summer's day?
Thou art more lovely and more temperate:
Rough winds do shake the darling buds of May,
And summer's lease hath all too short a date;
Sometime too hot the eye of heaven shines,
And often is his gold complexion dimm'd;
And every fair from fair sometime declines,
By chance or nature's changing course untrimm'd;
But thy eternal summer shall not fade,
Nor lose possession of that fair thou ow'st;
Nor shall death brag thou wander'st in his shade,
When in eternal lines to time thou grow'st:
So long as men can breathe or eyes can see,
So long lives this, and this gives life to thee.

To be, or not to be, that is the question:
Whether 'tis nobler in the mind to suffer
The slings and arrows of outrageous fortune,
Or to take arms against a sea of troubles
And by opposing end them. To die: to sleep;
No more; and by a sleep to say we end
The heart-ache and the thousand natural shocks
That flesh is heir to, 'tis a consummation
Devoutly to be wish'd. To die, to sleep;
To sleep: perchance to dream: ay, there's the rub;
For in that sleep of death what dreams may come
When we have shuffled off this mortal coil,
Must give us pause: there's the respect
That makes calamity of so long life;

All the world's a stage,
And all the men and women merely players;
They have their exits and their entrances,
And one man in his time plays many parts,
His acts being seven ages. At first the infant,
Mewling and puking in the nurse's arms;
And then the whining school-boy, with his satchel
And shining morning face, creeping like snail
Unwillingly to school. And then the lover,
Sighing like furnace, with a woeful ballad
Made to his mistress' eyebrow. Then a soldier,
Full of strange oaths, and bearded like the pard,
Jealous in honour, sudden and quick in quarrel,
Seeking the bubble reputation
Even in the cannon's mouth.

Friends, Romans, countrymen, lend me your ears;
I come to bury Caesar, not to praise him.
The evil that men do lives after them;
The good is oft interred with their bones;
So let it be with Caesar. The noble Brutus
Hath told you Caesar was ambitious:
If it were so, it was a grievous fault,
And grievously hath Caesar answer'd it.

Now is the winter of our discontent
Made glorious summer by this sun of York;
And all the clouds that lour'd upon our house
In the deep bosom of the ocean buried.
Now are our brows bound with victorious wreaths;
Our bruised arms hung up for monuments;
Our stern alarums changed to merry meetings,
Our dreadful marches to delightful measures.
"""

CHAR_VOCAB = sorted(set(_EXCERPT))
_STOI = {c: i for i, c in enumerate(CHAR_VOCAB)}
VOCAB_SIZE = len(CHAR_VOCAB)


def load_shakespeare(n_chars: int = 200_000, seed: int = 0) -> np.ndarray:
    """Return an int32 token stream of ~n_chars characters."""
    rng = np.random.default_rng(seed)
    lines = [l for l in _EXCERPT.strip().split("\n\n")]
    chunks = []
    total = 0
    while total < n_chars:
        li = rng.integers(0, len(lines))
        chunks.append(lines[li] + "\n\n")
        total += len(chunks[-1])
    text = "".join(chunks)[:n_chars]
    return np.array([_STOI[c] for c in text], np.int32)


def char_batches(stream: np.ndarray, batch: int, seq: int,
                 rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray]:
    """Sample (inputs, targets) next-char pairs of shape (batch, seq)."""
    starts = rng.integers(0, stream.shape[0] - seq - 1, batch)
    x = np.stack([stream[s:s + seq] for s in starts])
    y = np.stack([stream[s + 1:s + seq + 1] for s in starts])
    return x, y
