"""Pallas TPU kernel: fused error-feedback layered sparsification.

The LGC hot path (Algorithm 1 lines 8-11) per element is

    u  = e + delta
    g  = u * 1[ layer(|u|) received ]
    e' = u - g

Unfused, this costs 5 HBM round-trips over D-sized vectors (read e, read
delta, write u, read u, write g, write e').  The fused kernel reads e and
delta once and writes g and e' once -- 4 D-sized transfers, the HBM lower
bound -- recomputing u in VMEM.  Layer membership is a chain of C threshold
comparisons against scalar bin edges produced by
:mod:`repro.kernels.topk_threshold` (C is static, <= 4 channels).  The
fused output must preserve the EF identity u == g + e' bit-exactly
(tests/test_kernels.py::TestSparsifyEF).

Blocks are (block_rows, 128) VMEM tiles over the lane-major view of the
flat gradient, same layout as the statistics kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .topk_threshold import LANES, _as_rows


def _sparsify_ef_kernel(e_ref, d_ref, thr_ref, recv_ref, g_ref, enew_ref, *,
                        n_layers: int):
    u = e_ref[...].astype(jnp.float32) + d_ref[...].astype(jnp.float32)
    a = jnp.abs(u)
    g = jnp.zeros_like(u)
    hi = jnp.float32(jnp.inf)
    for c in range(n_layers):          # static unroll, C <= 4
        lo = thr_ref[0, c]
        mask = (a <= hi) & (a > lo)
        take = mask & (recv_ref[0, c] > 0)
        g = g + jnp.where(take, u, 0.0)
        hi = lo
    g_ref[...] = g.astype(g_ref.dtype)
    enew_ref[...] = (u - g).astype(enew_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret"))
def sparsify_ef(e: jax.Array, delta: jax.Array, thr: jax.Array,
                received: jax.Array, *, block_rows: int = 64,
                interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Fused layered sparsify + error-feedback update on flat vectors.

    Args:
      e, delta: (D,) error memory and net progress.
      thr: (C,) descending layer thresholds (bin edges).
      received: (C,) int32/bool channel delivery mask.

    Returns (g, e_new), both (D,) float32.
    """
    d = e.shape[0]
    n_layers = thr.shape[0]
    er, n_blocks, _ = _as_rows(e.astype(jnp.float32), block_rows)
    dr, _, _ = _as_rows(delta.astype(jnp.float32), block_rows)
    kernel = functools.partial(_sparsify_ef_kernel, n_layers=n_layers)
    g, e_new = pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, n_layers), lambda i: (0, 0)),
            pl.BlockSpec((1, n_layers), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(er.shape, jnp.float32),
            jax.ShapeDtypeStruct(er.shape, jnp.float32),
        ],
        interpret=interpret,
    )(er, dr, thr.reshape(1, -1).astype(jnp.float32),
      received.reshape(1, -1).astype(jnp.int32))
    return g.reshape(-1)[:d], e_new.reshape(-1)[:d]
