"""Population layer invariants (docs/ARCHITECTURE.md §8).

Three contracts are pinned here:

* **Cohort sampling** (TestCohortSampling, property-based): every drawn id
  is a valid unique global device id, zero-weight devices are never drawn,
  and the draw is a pure function of (seed, window-start round) -- the
  TAG_COHORT stream has no device or mesh-layout dependence, so any engine
  blocking consumes the identical cohort.
* **EF stores** (TestEFStores): dense gather/scatter roundtrips bitwise;
  int8 decodes within max|e|/254 per element at <= 30% of dense bytes;
  the server-side store broadcasts one shared residual and keeps the
  cohort mean.
* **Sampled-cohort equivalence** (TestPopulationEquivalence): at
  N = 100k, M = 64, population loop == batched History is BIT-identical
  with the dense store (static and gilbert_flaky scenarios), allclose
  within pinned tolerance with the int8 store, and sharded == batched
  bitwise on the present mesh -- the population rungs of the engine
  ladder.  The CI test-sharded lane re-runs this file on a forced
  8-device host mesh.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import FLConfig
from repro.core.error_feedback import (EF_STORES, DenseEFStore, Int8EFStore,
                                       ServerEFStore, make_ef_store)
from repro.core.population import (COHORT_SAMPLERS, make_population,
                                   make_population_task, run_population,
                                   sample_cohort)
from repro.core.scenario import TAG_COHORT, stream_key

N_POP = 100_000
M_COHORT = 64


@pytest.fixture(scope="module")
def task():
    return make_population_task(n_shards=8, n_train=1024, seed=0)


def _hist(task, *, ef_store="dense", scenario=None, engine="batched",
          mesh=None, seed=0):
    pop = make_population(task, N_POP, ef_store=ef_store, scenario=scenario)
    cfg = FLConfig(rounds=8, eval_every=4, seed=seed,
                   scenario=scenario or "static")
    return run_population(pop, cfg, "lgc", h=4, m_cohort=M_COHORT,
                          engine=engine, mesh=mesh).asdict()


class TestCohortSampling:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(min_value=100, max_value=5000),
           st.integers(min_value=1, max_value=64),
           st.integers(min_value=0, max_value=1000))
    def test_ids_valid_and_unique(self, n, m, t):
        base = jax.random.PRNGKey(7)
        ids = sample_cohort(base, "uniform", n, min(m, n), t)
        assert ids.min() >= 0 and ids.max() < n
        assert len(set(ids.tolist())) == len(ids)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000),
           st.integers(min_value=0, max_value=99))
    def test_deterministic_per_seed_round(self, seed, t):
        base = jax.random.PRNGKey(seed)
        a = sample_cohort(base, "uniform", 4096, 32, t)
        b = sample_cohort(base, "uniform", 4096, 32, t)
        assert (a == b).all()

    def test_round_changes_draw(self):
        base = jax.random.PRNGKey(0)
        a = sample_cohort(base, "uniform", 4096, 32, 0)
        b = sample_cohort(base, "uniform", 4096, 32, 4)
        assert not (np.sort(a) == np.sort(b)).all()

    @settings(max_examples=15, deadline=None)
    @given(st.integers(min_value=0, max_value=500))
    def test_zero_weights_never_drawn(self, t):
        n = 2048
        w = np.ones(n)
        w[::2] = 0.0                       # every even device excluded
        ids = sample_cohort(jax.random.PRNGKey(3), "weighted", n, 64, t, w)
        assert (ids % 2 == 1).all()

    def test_keyed_by_seed_and_round_only(self):
        """The draw is reproducible straight from the TAG_COHORT stream --
        no device ids, no mesh state, no consumption order feed into the
        key, which is what makes the cohort mesh-layout invariant (the
        sharded==batched population test exercises the full window)."""
        base = jax.random.PRNGKey(11)
        ids = sample_cohort(base, "uniform", 4096, 32, 17)
        expect = jax.random.choice(stream_key(base, TAG_COHORT, 17),
                                   4096, (32,), replace=False)
        assert (ids == np.asarray(expect)).all()

    def test_weighted_matches_weight_ratios(self):
        n = 1000
        w = np.ones(n)
        w[:100] = 9.0                      # 10% of devices, 50% of mass
        counts = np.zeros(n)
        base = jax.random.PRNGKey(5)
        for t in range(200):
            counts[sample_cohort(base, "weighted", n, 32, t, w)] += 1
        heavy = counts[:100].sum() / counts.sum()
        assert 0.3 < heavy < 0.7           # loose: biased well above 10%

    def test_rejects_bad_inputs(self):
        base = jax.random.PRNGKey(0)
        with pytest.raises(ValueError):
            sample_cohort(base, "nope", 100, 10, 0)
        with pytest.raises(ValueError):
            sample_cohort(base, "uniform", 100, 101, 0)
        with pytest.raises(ValueError):
            sample_cohort(base, "weighted", 100, 10, 0, -np.ones(100))
        with pytest.raises(ValueError):    # more draws than positive weights
            sample_cohort(base, "weighted", 100, 10, 0,
                          np.r_[np.ones(5), np.zeros(95)])

    def test_registry_names(self):
        assert set(COHORT_SAMPLERS) == {"uniform", "weighted"}
        assert set(EF_STORES) == {"dense", "int8", "server"}


class TestEFStores:
    def test_dense_roundtrip_exact(self):
        rng = np.random.default_rng(0)
        store = DenseEFStore(100, 32)
        ids = np.array([3, 17, 50, 99])
        ef = rng.normal(size=(4, 32)).astype(np.float32)
        store.scatter(ids, ef)
        assert (np.asarray(store.gather(ids)) == ef).all()
        # untouched rows stay zero
        assert (np.asarray(store.gather(np.array([0, 1]))) == 0).all()

    def test_int8_error_bound(self):
        rng = np.random.default_rng(1)
        store = Int8EFStore(100, 64)
        ids = np.arange(10)
        ef = (rng.normal(size=(10, 64)) * 10).astype(np.float32)
        store.scatter(ids, ef)
        dec = np.asarray(store.gather(ids))
        bound = np.abs(ef).max(axis=1, keepdims=True) / 254.0
        assert (np.abs(dec - ef) <= bound + 1e-7).all()

    def test_int8_zero_row_safe(self):
        store = Int8EFStore(4, 16)
        store.scatter(np.array([2]), np.zeros((1, 16), np.float32))
        assert (np.asarray(store.gather(np.array([2]))) == 0).all()

    def test_int8_bytes_ratio(self):
        n, d = 1000, 68                    # the population task's D
        ratio = Int8EFStore(n, d).nbytes / DenseEFStore(n, d).nbytes
        assert ratio <= 0.30
        # the ratio is (D + 4) / (4 D): <= 30% for any D >= 20
        assert Int8EFStore(n, 20).nbytes / DenseEFStore(n, 20).nbytes <= 0.30

    def test_server_store_semantics(self):
        store = ServerEFStore(1000, 8)
        ids = np.array([1, 500, 999])
        ef = np.arange(24, dtype=np.float32).reshape(3, 8)
        store.scatter(ids, ef)
        got = np.asarray(store.gather(np.array([7, 42])))
        # every cohort row sees the same shared residual: the cohort mean
        assert got.shape == (2, 8)
        np.testing.assert_allclose(
            got, np.broadcast_to(ef.mean(axis=0), (2, 8)))
        assert store.nbytes == 8 * 4       # O(D), independent of N

    def test_make_ef_store_rejects_unknown(self):
        with pytest.raises(ValueError):
            make_ef_store("float16", 10, 10)


class TestPopulationEquivalence:
    """The sampled-cohort rungs of the engine ladder at N=100k, M=64."""

    @pytest.mark.parametrize("scenario", [None, "gilbert_flaky"])
    def test_loop_matches_batched_bitwise_dense(self, task, scenario):
        hb = _hist(task, engine="batched", scenario=scenario)
        hl = _hist(task, engine="loop", scenario=scenario)
        assert hb == hl                    # BIT-identical, dense EF store

    def test_loop_matches_batched_int8_pinned_tol(self, task):
        """Contract: allclose within 1e-6 for the quantized store (in
        practice both engines decode the same codes, so it is bitwise --
        the contract only promises the tolerance)."""
        hb = _hist(task, engine="batched", ef_store="int8")
        hl = _hist(task, engine="loop", ef_store="int8")
        assert hb["step"] == hl["step"]
        for k in ("loss", "accuracy", "energy_j", "money", "time_s",
                  "uplink_mb"):
            np.testing.assert_allclose(hb[k], hl[k], rtol=0, atol=1e-6)

    def test_sharded_matches_batched_bitwise(self, task):
        """On the present mesh (CI re-runs under a forced 8-device host
        platform); M=64 divides any power-of-two device count."""
        hb = _hist(task, engine="batched", scenario="gilbert_flaky")
        hs = _hist(task, engine="sharded", scenario="gilbert_flaky")
        assert hb == hs

    def test_engine_and_seed_validation(self, task):
        pop = make_population(task, 1000)
        with pytest.raises(ValueError):
            run_population(pop, FLConfig(rounds=4), engine="warp")
        with pytest.raises(ValueError):
            run_population(pop, FLConfig(rounds=4, seed=3))   # pop seed 0
        with pytest.raises(ValueError):    # scenario mismatch
            run_population(pop, FLConfig(rounds=4, scenario="gilbert_flaky"))


class TestPopulationBehaviour:
    def test_convergence_smoke(self, task):
        pop = make_population(task, 20_000)
        cfg = FLConfig(rounds=24, eval_every=8)
        h = run_population(pop, cfg, "lgc", h=4, m_cohort=32)
        assert h.loss[-1] < h.loss[0]
        assert h.accuracy[-1] > 0.6
        assert int(pop.participation.sum()) == 32 * 6   # 6 windows of 32
        assert pop.participation.max() <= 6

    def test_weighted_population_excludes_zero_weight(self, task):
        n = 5000
        w = np.ones(n)
        w[: n // 2] = 0.0
        pop = make_population(task, n, sampler="weighted", weights=w)
        run_population(pop, FLConfig(rounds=8), "lgc", h=4, m_cohort=16)
        assert pop.participation[: n // 2].sum() == 0
        assert pop.participation[n // 2:].sum() == 16 * 2

    def test_fedavg_mode_runs(self, task):
        pop = make_population(task, 5000)
        h = run_population(pop, FLConfig(rounds=8, eval_every=4), "fedavg",
                           h=4, m_cohort=16)
        assert len(h.step) == 3 and h.uplink_mb[-1] > 0
