"""Dynamic-environment scenarios: time-correlated channels, heterogeneous
data, device dropout/stragglers (the paper's "highly dynamic" edge network).

The seed channel model (:mod:`repro.core.channels`) is memoryless: per-round
lognormal bandwidth jitter and IID Bernoulli availability.  A DDPG controller
benchmarked on it has no temporal structure to exploit.  This module bundles
the *dynamics* of a simulation into a :class:`Scenario`:

* **Gauss-Markov bandwidth** -- the log-bandwidth deviation x_c of every
  channel follows a stationary AR(1) chain

      x_{t+1} = rho * x_t + sigma * sqrt(1 - rho^2) * n_t,   n_t ~ N(0, 1)

  with stationary distribution N(0, sigma^2).  The realized bandwidth is
  ``nominal * exp(x_t - sigma^2/2)``, whose long-run mean is exactly the
  spec's nominal rate (the -sigma^2/2 cancels the lognormal mean shift) --
  pinned by the stationarity test in tests/test_scenarios.py.

* **Gilbert-Elliott availability** -- each channel is a two-state (good/bad)
  Markov chain: P(good->bad) = p_gb, P(bad->good) = p_bg; the channel is up
  iff it is in the good state.  Stationary availability is
  ``p_bg / (p_gb + p_bg)``.  Burst losses (consecutive bad rounds last
  1/p_bg rounds in expectation) are what layered coding + error feedback
  degrade gracefully under.

* **Dropout / stragglers** -- per-device profiles: a dropped device's sync
  round loses its ENTIRE uplink (all layers down; the error-feedback residual
  carries the undelivered mass to the next sync) while the downlink broadcast
  still reaches it; stragglers pay a compute-time multiplier in the cost
  model.

Both chains are pure ``(carry, key) -> (carry, ...)`` functions driven by the
counter-based :func:`stream_key` scheme (which lives here so the scenario
layer sits below :mod:`repro.core.fl`): the loop engine advances one vmapped
step per round, the batched engine threads the carry through its window scan,
and the sharded engine shards the (M, C) carry over the mesh -- all three
consume identical variates, so the loop==batched==sharded equivalence
invariant extends to every scenario (tests/test_scenarios.py).

``FLConfig.scenario`` accepts a :class:`Scenario` or a registry name --
see :data:`SCENARIOS` ("static", "markov_urban", "gilbert_flaky", ...).

Invariants (and who enforces them):

* every per-(round, device) stream is keyed by *global* device id through
  :func:`stream_key`, never by shard-local position, so the same simulation
  produces bit-identical variates on any mesh layout --
  tests/test_scenarios.py (loop==batched==sharded per scenario) and
  tests/test_population.py::TestCohortSampling (TAG_COHORT mesh invariance);
* ``valid``-masked chain steps leave the carry bitwise untouched, so window
  padding cannot desynchronize engines -- tests/test_scenarios.py;
* chain marginals match their stationary distributions --
  tests/test_scenarios.py::TestChainStationarity.

The carry-threading contract and the TAG registry are documented in
docs/ARCHITECTURE.md §3/§5; the population cohort stream (TAG_COHORT,
keyed per sync window, not per device) in §8.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from .channels import (ChannelConstants, ChannelSample, DeviceProfile,
                       sample_channels_from)

Array = jax.Array


# ---------------------------------------------------------------------------
# counter-based randomness, shared by all engines (moved here from fl.py so
# the scenario layer has no circular dependency; fl.py re-exports)
# ---------------------------------------------------------------------------

# stream tags: minibatch draws, channel realisations, eval subsets,
# controller-reward eval subsets, QSGD dither, controller exploration noise,
# controller replay sampling, scenario chain transitions, scenario chain
# stationary init, sync-round device dropout, population cohort draws
(TAG_BATCH, TAG_CHANNEL, TAG_EVAL, TAG_REWARD, TAG_QUANT,
 TAG_CTRL_NOISE, TAG_CTRL_SAMPLE, TAG_SCEN, TAG_SCEN_INIT,
 TAG_DROP, TAG_COHORT) = range(11)


def stream_key(base: Array, tag: int, *ids) -> Array:
    """Derive the PRNG key for one (stream, round, device) event.

    Counter-based (``fold_in`` of static tags + indices) instead of a split
    chain, so the loop engine (sequential consumption) and the batched engine
    (vmapped consumption inside a scan) draw bit-identical variates.
    """
    k = jax.random.fold_in(base, tag)
    for i in ids:
        k = jax.random.fold_in(k, i)
    return k


# ---------------------------------------------------------------------------
# dynamics specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GaussMarkovSpec:
    """AR(1) log-bandwidth evolution (replaces the IID lognormal jitter)."""
    rho: float = 0.95       # per-round memory; 0 degenerates to IID
    sigma: float = 0.4      # stationary std of the log-bandwidth deviation


@dataclasses.dataclass(frozen=True)
class GilbertElliottSpec:
    """Two-state good/bad chain (replaces IID Bernoulli availability)."""
    p_gb: float = 0.05      # P(good -> bad) per round
    p_bg: float = 0.4       # P(bad -> good) per round

    @property
    def stationary_availability(self) -> float:
        return self.p_bg / (self.p_gb + self.p_bg)


@dataclasses.dataclass(frozen=True)
class DropoutSpec:
    """Per-device sync-round dropout: the whole uplink is lost, the EF
    residual carries the undelivered mass, the downlink still arrives."""
    base_prob: float = 0.0      # every device's per-sync drop probability
    flaky_every: int = 0        # every k-th device is flaky (0 = none)
    flaky_prob: float = 0.0     # drop probability of the flaky devices


@dataclasses.dataclass(frozen=True)
class StragglerSpec:
    """Every k-th device computes ``slowdown``x slower (wall-clock cost)."""
    slow_every: int = 0
    slowdown: float = 1.0


@dataclasses.dataclass(frozen=True)
class HeteroFleetSpec:
    """Skewed per-device traits, cycled by *global* device id.

    Device ``i`` gets ``batteries[i % len]`` state of charge and
    ``compute_mults[i % len]`` as a multiplier on both compute energy and
    compute time -- the heterogeneity the per-device controller observes
    (battery + compute multiplier land in the profile-augmented state
    vector, docs/ARCHITECTURE.md §13).  Cycling by global id keeps the
    assignment shard-layout independent, like :meth:`Scenario.drop_probs`.

    The default ladder is healthy-majority / weak-tail: three full-battery
    tiers of increasing compute cost plus two battery-poor stragglers whose
    decode clamp (h <= 1 + floor(soc * (h_max-1))) bites at h_max=4.  A
    deeper poverty tier (e.g. battery 0.1, pinned at h=1) starves that
    device's data shard outright under plain-mean aggregation and turns the
    scenario into an aggregator-weighting benchmark instead of a
    controller benchmark.
    """
    batteries: Sequence[float] = (1.0, 1.0, 1.0, 0.7, 0.67)
    compute_mults: Sequence[float] = (1.0, 1.0, 1.5, 2.5, 4.0)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Bundle of channel dynamics, data heterogeneity and device dynamics.

    ``gauss_markov`` / ``gilbert_elliott`` being None keeps the seed model's
    memoryless bandwidth jitter / Bernoulli availability for that component;
    a fully-None scenario ("static") reproduces the seed behaviour exactly.
    ``partition``/``alpha`` describe how task factories should shard data
    (see :func:`repro.models.paper_models.make_mnist_task`); the engines
    themselves never look at them.
    """
    name: str = "static"
    gauss_markov: GaussMarkovSpec | None = None
    gilbert_elliott: GilbertElliottSpec | None = None
    dropout: DropoutSpec | None = None
    straggler: StragglerSpec | None = None
    hetero: HeteroFleetSpec | None = None
    partition: str = "iid"          # "iid" | "noniid" | "dirichlet" | "quantity"
    alpha: float = 0.5              # Dirichlet concentration (data skew)

    @property
    def is_static(self) -> bool:
        """True when per-round carry advancement is a no-op."""
        return self.gauss_markov is None and self.gilbert_elliott is None

    @property
    def has_dropout(self) -> bool:
        d = self.dropout
        return d is not None and (d.base_prob > 0 or
                                  (d.flaky_every > 0 and d.flaky_prob > 0))

    def device_profile_at(self, i: int) -> DeviceProfile:
        """Compute profile of *global* device ``i`` (straggler rule applied).

        Keyed by global device id so population cohorts (which materialize
        profiles only for the M sampled devices, never all N) agree with a
        full-participation run over the same ids -- the same global-id rule
        as :meth:`drop_probs` and the carry streams.  The ``hetero`` skew
        (battery + compute multiplier) applies first; a straggler slowdown
        multiplies on top."""
        base = DeviceProfile()
        h = self.hetero
        if h is not None:
            battery = float(h.batteries[i % len(h.batteries)])
            mult = float(h.compute_mults[i % len(h.compute_mults)])
            base = DeviceProfile(
                name=f"{base.name}-hetero{i % len(h.batteries)}",
                comp_j_per_step=base.comp_j_per_step * mult,
                comp_time_per_step_s=base.comp_time_per_step_s * mult,
                battery=battery)
        s = self.straggler
        if (s is None or s.slow_every <= 0 or s.slowdown == 1.0
                or i % s.slow_every != 0):
            return base
        return DeviceProfile(
            name=f"{base.name}-straggler",
            comp_j_per_step=base.comp_j_per_step * s.slowdown,
            comp_time_per_step_s=base.comp_time_per_step_s * s.slowdown,
            battery=base.battery)

    def device_profiles(self, m: int) -> list[DeviceProfile]:
        """Per-device compute profiles with the straggler slowdown applied."""
        return [self.device_profile_at(i) for i in range(m)]

    def drop_probs(self, dev_ids: Array) -> Array:
        """(M,) per-device sync-dropout probabilities from *global* device
        ids (shard-layout independent)."""
        d = self.dropout or DropoutSpec()
        p = jnp.full(dev_ids.shape, d.base_prob, jnp.float32)
        if d.flaky_every > 0:
            p = jnp.where(dev_ids % d.flaky_every == 0, d.flaky_prob, p)
        return p


class ScenarioCarry(NamedTuple):
    """Per-device chain state threaded through the engines.

    Shapes are per device: stacked to (M, C) by the engines, sharded to
    (M/D, C) blocks by :class:`~repro.core.fl_batched.ShardedEngine`.  For
    static components the fields are carried but never read (XLA dead-code
    eliminates them inside the window program).
    """
    bw_log: Array       # (C,) f32  AR(1) log-bandwidth deviation
    good: Array         # (C,) bool Gilbert-Elliott state (True = good)


def init_carry(scn: Scenario, base: Array, dev_id: Array,
               n_channels: int) -> ScenarioCarry:
    """Stationary-draw initial chain state for one device (TAG_SCEN_INIT)."""
    k = stream_key(base, TAG_SCEN_INIT, dev_id)
    k_gm, k_ge = jax.random.split(k)
    gm, ge = scn.gauss_markov, scn.gilbert_elliott
    if gm is not None:
        bw_log = gm.sigma * jax.random.normal(k_gm, (n_channels,))
    else:
        bw_log = jnp.zeros((n_channels,), jnp.float32)
    if ge is not None:
        good = (jax.random.uniform(k_ge, (n_channels,))
                < ge.stationary_availability)
    else:
        good = jnp.ones((n_channels,), bool)
    return ScenarioCarry(bw_log.astype(jnp.float32), good)


def step_carry(scn: Scenario, base: Array, carry: ScenarioCarry, t: Array,
               dev_id: Array, valid: Array) -> ScenarioCarry:
    """Advance one device's chains through round ``t`` (TAG_SCEN stream).

    ``valid`` masks padded scan rounds: invalid steps leave the carry
    bitwise untouched, so the batched engine's power-of-two window padding
    cannot desynchronize the chains from the loop engine.
    """
    if scn.is_static:
        return carry
    k = stream_key(base, TAG_SCEN, t, dev_id)
    k_gm, k_ge = jax.random.split(k)
    bw_log, good = carry
    gm, ge = scn.gauss_markov, scn.gilbert_elliott
    if gm is not None:
        innov = gm.sigma * jnp.sqrt(1.0 - gm.rho ** 2) * \
            jax.random.normal(k_gm, bw_log.shape)
        bw_log = jnp.where(valid, gm.rho * bw_log + innov, bw_log)
    if ge is not None:
        u = jax.random.uniform(k_ge, good.shape)
        good = jnp.where(valid,
                         jnp.where(good, u >= ge.p_gb, u < ge.p_bg), good)
    return ScenarioCarry(bw_log, good)


def sample_from_carry(scn: Scenario, consts: ChannelConstants,
                      carry: ScenarioCarry, key: Array) -> ChannelSample:
    """Realise one device's channel conditions at a sync round.

    Delegates to :func:`repro.core.channels.sample_channels_from` and then
    overlays the carry-driven fields, so static components consume exactly
    the seed model's sub-keys / variates *by construction* (XLA dead-code
    eliminates the replaced draws) and a fully-static scenario reproduces it
    bit-for-bit.
    """
    s = sample_channels_from(key, consts)
    gm, ge = scn.gauss_markov, scn.gilbert_elliott
    if gm is not None:
        # exp(x - sigma^2/2): long-run mean is exactly the nominal rate
        s = s._replace(bandwidth_mb_s=consts.bw_nominal *
                       jnp.exp(carry.bw_log - 0.5 * gm.sigma ** 2))
    if ge is not None:
        s = s._replace(up=carry.good)
    return s


def dropout_mask(scn: Scenario, base: Array, t: Array, dev_ids: Array
                 ) -> Array:
    """(M,) bool: which devices lose their whole uplink at sync round ``t``.

    Keyed per (round, device) on TAG_DROP, so engines agree regardless of
    which devices actually sync (counter-based keys have no consumption
    state)."""
    if not scn.has_dropout:
        return jnp.zeros(dev_ids.shape, bool)
    u = jax.vmap(
        lambda i: jax.random.uniform(stream_key(base, TAG_DROP, t, i)))(
        dev_ids)
    return u < scn.drop_probs(dev_ids)


# ---------------------------------------------------------------------------
# named-scenario registry
# ---------------------------------------------------------------------------

SCENARIOS: dict[str, Scenario] = {
    # the seed environment: memoryless jitter, IID availability, IID data
    "static": Scenario(name="static"),
    # dense urban mobility: slowly-wandering bandwidth, occasional short
    # outage bursts (shadowing around corners)
    "markov_urban": Scenario(
        name="markov_urban",
        gauss_markov=GaussMarkovSpec(rho=0.95, sigma=0.5),
        gilbert_elliott=GilbertElliottSpec(p_gb=0.05, p_bg=0.5)),
    # highway handovers: fast-decorrelating bandwidth, frequent but brief
    # outages
    "markov_highway": Scenario(
        name="markov_highway",
        gauss_markov=GaussMarkovSpec(rho=0.7, sigma=0.8),
        gilbert_elliott=GilbertElliottSpec(p_gb=0.15, p_bg=0.6)),
    # bursty loss regime + flaky devices: every 4th device drops whole sync
    # uploads 30% of the time -- the graceful-degradation stress test
    "gilbert_flaky": Scenario(
        name="gilbert_flaky",
        gilbert_elliott=GilbertElliottSpec(p_gb=0.2, p_bg=0.3),
        dropout=DropoutSpec(base_prob=0.05, flaky_every=4, flaky_prob=0.3)),
    # statistical heterogeneity only: Dirichlet(0.3) label skew, static net
    "dirichlet0.3": Scenario(
        name="dirichlet0.3", partition="dirichlet", alpha=0.3),
    # heterogeneous fleet: skewed battery / compute-speed traits on top of
    # correlated channels -- the per-device controller's home turf (a
    # uniform policy over-spends the weak devices' batteries).  Data stays
    # IID on purpose: hardware skew is this scenario's axis; pairing label
    # skew with pinned-down devices measures the plain-mean aggregator's
    # missing-class drag, not the controller (mobile_noniid owns data skew)
    "hetero_fleet": Scenario(
        name="hetero_fleet",
        gauss_markov=GaussMarkovSpec(rho=0.9, sigma=0.5),
        gilbert_elliott=GilbertElliottSpec(p_gb=0.1, p_bg=0.4),
        hetero=HeteroFleetSpec()),
    # the kitchen sink: correlated channels + skewed data + flaky stragglers.
    # The battery ladder is phase-locked to StragglerSpec(slow_every=4): the
    # i % 4 == 0 straggler tier is also the battery-poor one, so a
    # per-device controller can cap exactly the devices whose steps cost 3x.
    # compute_mults stay 1.0 -- battery only enters the per-device
    # observation and the decode clamp, so the fixed / shared-DDPG cost
    # model (and their committed bench baselines) are untouched.
    "mobile_noniid": Scenario(
        name="mobile_noniid",
        gauss_markov=GaussMarkovSpec(rho=0.9, sigma=0.5),
        gilbert_elliott=GilbertElliottSpec(p_gb=0.1, p_bg=0.4),
        dropout=DropoutSpec(base_prob=0.02, flaky_every=4, flaky_prob=0.2),
        straggler=StragglerSpec(slow_every=4, slowdown=3.0),
        hetero=HeteroFleetSpec(batteries=(0.55, 1.0, 1.0, 1.0),
                               compute_mults=(1.0, 1.0, 1.0, 1.0)),
        partition="dirichlet", alpha=0.3),
}


def get_scenario(scenario: str | Scenario | None) -> Scenario:
    """Resolve a registry name (or pass a Scenario through; None = static)."""
    if scenario is None:
        return SCENARIOS["static"]
    if isinstance(scenario, Scenario):
        return scenario
    try:
        return SCENARIOS[scenario]
    except KeyError:
        raise ValueError(
            f"unknown scenario {scenario!r}; registered: "
            f"{sorted(SCENARIOS)}") from None


def partition_fn(scn: Scenario):
    """The data partitioner named by ``scn.partition`` as
    ``f(x, y, m, seed) -> [(x_i, y_i)]`` (resolved lazily to keep this
    module importable without the data package)."""
    from repro.data import (partition_dirichlet, partition_iid,
                            partition_noniid, partition_quantity_skew)
    if scn.partition == "iid":
        return lambda x, y, m, seed: partition_iid(x, y, m, seed)
    if scn.partition == "noniid":
        return lambda x, y, m, seed: partition_noniid(x, y, m, seed=seed)
    if scn.partition == "dirichlet":
        return lambda x, y, m, seed: partition_dirichlet(
            x, y, m, alpha=scn.alpha, seed=seed)
    if scn.partition == "quantity":
        return lambda x, y, m, seed: partition_quantity_skew(
            x, y, m, alpha=scn.alpha, seed=seed)
    raise ValueError(f"unknown partition {scn.partition!r}")
