"""Decision-log cost auditing: recompute History spend from first principles.

The engines account energy / money / wall-time / bytes incrementally at every
sync (``LGCSimulator._sync_device``, ``BatchedEngine.run``).  Because every
cost depends only on *committed controller decisions* and *counter-based
channel randomness* -- never on gradient values -- the whole spend ledger can
be recomputed after the fact from

    (FLConfig, mode, model size d, device profiles, decision_log)

by replaying the scenario chains and pricing each logged decision's sync
round.  :func:`recompute_spend` does exactly that, mirroring the loop
engine's host accounting (f32 channel math, integer byte counts, f64
accumulation in sync order) so the totals are *identical*, not just close.

This closes the accounting gap the benchmarks could never catch: an engine
that silently drifts its cost bookkeeping (wrong sync round, dropped
channel mask, comp cost with the wrong h) now fails the cross-engine
cost-conservation property test
(tests/test_hetero_control.py::TestCostConservation) instead of shipping a
wrong Pareto frontier.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .channels import comm_cost, comp_cost, stack_specs
from .compressor import wire_bytes
from .scenario import (TAG_CHANNEL, dropout_mask, get_scenario, init_carry,
                       sample_from_carry, step_carry, stream_key)


def sync_round_of(cfg, t_commit: int, h: int) -> int:
    """The round at which a decision committed at ``t_commit`` syncs.

    ``shared``: the device's own window is h rounds; ``per_device``: every
    window is max_gap rounds and h only masks compute steps inside it."""
    per_device = getattr(cfg, "action_space", "shared") == "per_device"
    return t_commit + (cfg.max_gap if per_device else h) - 1


def recompute_spend(cfg, mode: str, d: int, decision_log: Sequence[tuple],
                    m_devices: int, profiles=None) -> list[dict]:
    """Replay ``decision_log`` -> per-device spend dicts.

    ``decision_log`` rows are the simulator's ``(t_commit, m, h, ks)``
    tuples.  Decisions whose window runs past ``cfg.rounds`` never synced
    and cost nothing (exactly like the engines).  Returns a list of M dicts
    with keys energy_j / money / time_s / mb, f64-accumulated in the same
    per-device sync order the engines use."""
    scn = get_scenario(cfg.scenario)
    if profiles is None:
        profiles = scn.device_profiles(m_devices)
    profiles = list(profiles)
    base = jax.random.PRNGKey(cfg.seed + 1)
    n_ch = len(cfg.channels)
    consts = stack_specs(cfg.channels)
    dev_ids = jnp.arange(m_devices, dtype=jnp.int32)
    carry = jax.vmap(lambda i: init_carry(scn, base, i, n_ch))(dev_ids)
    # identical vmapped chain advance to LGCSimulator._scen_step_all, so the
    # realized ChannelSample at each sync round is the engines' bit-for-bit
    step_all = jax.jit(
        lambda c, t: jax.vmap(
            lambda ci, i: step_carry(scn, base, ci, t, i,
                                     jnp.bool_(True)))(c, dev_ids))

    syncs: dict[tuple[int, int], tuple[int, list[int]]] = {}
    for (t_commit, m, h, ks) in decision_log:
        t_sync = sync_round_of(cfg, t_commit, h)
        if t_sync < cfg.rounds:
            syncs[(t_sync, m)] = (int(h), list(ks))
    spend = [dict(energy_j=0.0, money=0.0, time_s=0.0, mb=0.0)
             for _ in range(m_devices)]
    if not syncs:
        return spend
    last = max(t for (t, _m) in syncs)

    for t in range(last + 1):
        if not scn.is_static:
            carry = step_all(carry, jnp.int32(t))
        for m in range(m_devices):
            if (t, m) not in syncs:
                continue
            h, ks = syncs[(t, m)]
            k_ch = stream_key(base, TAG_CHANNEL, t, m)
            carry_m = jax.tree_util.tree_map(lambda a: a[m], carry)
            ch = sample_from_carry(scn, consts, carry_m, k_ch)
            if scn.has_dropout:
                drop = dropout_mask(scn, base, t, dev_ids[m:m + 1])[0]
                ch = ch._replace(up=ch.up & ~drop)
            # byte accounting per mode, the loop engine's code verbatim
            if mode == "fedavg":
                any_up = bool(np.asarray(ch.up).any())
                bw = np.asarray(ch.bandwidth_mb_s) * np.asarray(ch.up)
                best = int(np.argmax(bw))
                nbytes = [0] * n_ch
                nbytes[best] = d * cfg.value_bytes if any_up else 0
            else:
                if mode == "topk":
                    ks = [sum(ks)] + [0] * (len(ks) - 1)
                vb = 1 if mode == "lgc_q8" else cfg.value_bytes
                received = [bool(u) for u in np.asarray(ch.up)][:len(ks)]
                received += [True] * (len(ks) - len(received))
                nbytes = wire_bytes(ks, vb, cfg.index_bytes)
                nbytes = [b if r else 0 for b, r in zip(nbytes, received)]
            cost = comm_cost(ch, nbytes)
            ccomp = comp_cost(profiles[m], h)
            s = spend[m]
            s["energy_j"] += float(cost["energy_j"]) + ccomp["energy_j"]
            s["money"] += float(cost["money"]) + ccomp["money"]
            s["time_s"] += float(cost["time_s"]) + ccomp["time_s"]
            s["mb"] += float(sum(nbytes)) / 1e6
    return spend


def audit_simulator(sim) -> tuple[list[dict], list[dict]]:
    """(recomputed, live) spend for a finished :class:`LGCSimulator` run."""
    recomputed = recompute_spend(sim.cfg, sim.mode, sim.d, sim.decision_log,
                                 sim.m_devices, profiles=sim.profiles)
    return recomputed, sim.spend
