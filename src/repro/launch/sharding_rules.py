"""Partition-spec trees for params, optimizer states, caches and batches.

Rules (Megatron-style tensor parallel on ``model`` + optional ZeRO-3 FSDP on
``data`` for >=4B archs, cfg.fsdp):

  embed (V,D)            -> ("model", None)          vocab-parallel
  lm_head (D,V)          -> (fsdp, "model")
  attn  wq/wk/wv (D,HK)  -> (fsdp, "model")    wo (HK,D) -> ("model", fsdp)
  mlp   up/gate (D,F)    -> (fsdp, "model")  down (F,D) -> ("model", fsdp)
  MoE experts (E,D,F)    -> expert-parallel ("model" on E) when E divides the
                            model axis; otherwise tensor-parallel on F
  ssm in_proj (D,P)      -> (fsdp, "model")  out_proj -> ("model", fsdp)
  norms / scalars        -> replicated

Stacked blocks carry a leading L axis -> specs get a leading None.
KV caches shard batch on "data" (when divisible) and the cache sequence axis
on "model" (sequence-parallel decode attention: works for any kv-head count).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig

Tree = Any


def _axis_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def param_specs(cfg: ArchConfig, params: Tree, mesh) -> Tree:
    """Spec tree matching ``params`` (built from its key paths)."""
    fsdp = "data" if cfg.fsdp else None
    model_n = _axis_size(mesh, "model")
    expert_parallel = cfg.n_experts > 0 and cfg.n_experts % model_n == 0

    def rule(path, leaf) -> P:
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = names[-1]
        stacked = names[0] in ("blocks", "enc_blocks")
        under_expert = "experts" in names

        def wrap(*spec):
            """Prefix the stacked-layer None (and expert dim for experts)."""
            out = list(spec)
            if under_expert:
                e_axis = "model" if expert_parallel else None
                out = [e_axis] + out
            if stacked:
                out = [None] + out
            # trim/pad to leaf rank
            out = out[: leaf.ndim]
            out += [None] * (leaf.ndim - len(out))
            return P(*out)

        if name == "embed":
            return P("model", None)
        if name == "lm_head":
            return P(fsdp, "model")
        if name == "vis_proj":
            return P(None, "model")
        if name in ("enc_pos", "dec_pos"):
            return P(None, None)
        if name in ("wq", "wk", "wv"):
            return wrap(fsdp, "model")
        if name == "wo":
            return wrap("model", fsdp)
        if name in ("bq", "bk", "bv"):
            return wrap("model")
        if name in ("w_gate", "w_up"):
            if under_expert and expert_parallel:
                return wrap(fsdp, None)
            if under_expert:
                return wrap(fsdp, "model")
            return wrap(fsdp, "model")
        if name == "w_down":
            if under_expert and expert_parallel:
                return wrap(None, fsdp)
            return wrap("model", fsdp)
        if name == "b_up":
            if under_expert and expert_parallel:
                return wrap(None)
            return wrap("model")
        if name == "router":
            return wrap(fsdp, None)
        if name == "in_proj":
            return wrap(fsdp, "model")
        if name == "out_proj":
            return wrap("model", fsdp)
        # norms, biases, conv weights, ssm scalars, everything small
        return wrap()

    return jax.tree_util.tree_map_with_path(rule, params)


def opt_state_specs(param_spec_tree: Tree, opt_state) -> Tree:
    """Optimizer-state specs: moments inherit the param spec, step is P()."""
    from repro.optim.optimizers import AdamWState, SGDMState, SGDState
    if isinstance(opt_state, AdamWState):
        return AdamWState(P(), param_spec_tree, param_spec_tree)
    if isinstance(opt_state, SGDMState):
        return SGDMState(P(), param_spec_tree)
    return SGDState(P())


def batch_specs(cfg: ArchConfig, batch: dict, mesh,
                include_pod: bool = True) -> dict:
    """Token batches: batch axis over ("pod","data") when divisible."""
    dp = _axis_size(mesh, "data")
    pods = _axis_size(mesh, "pod") if include_pod else 1
    out = {}
    for k, v in batch.items():
        b = v.shape[0]
        if b % (dp * pods) == 0:
            ax = ("pod", "data") if (pods > 1 and include_pod) else "data"
        elif b % dp == 0:
            ax = "data"
        else:
            ax = None
        out[k] = P(ax, *([None] * (v.ndim - 1)))
    return out


def cache_specs(cfg: ArchConfig, cache: Tree, mesh) -> Tree:
    """Decode-cache specs (see module docstring)."""
    dp = _axis_size(mesh, "data")
    model_n = _axis_size(mesh, "model")

    def rule(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = names[-1]
        if name == "pos":
            return P()
        batch_ok = leaf.ndim >= 2 and leaf.shape[1] % dp == 0
        b_ax = "data" if batch_ok else None
        if name in ("k", "v"):                    # (L, B, Kv, S, hd)
            s_ax = "model" if leaf.shape[3] % model_n == 0 else None
            return P(None, b_ax, None, s_ax, None)
        if name in ("cross_k", "cross_v"):        # (L, B, H, Senc, hd)
            return P(None, b_ax, None, None, None)
        if name == "conv":                        # (L, B, K-1, C)
            c_ax = "model" if leaf.shape[3] % model_n == 0 else None
            return P(None, b_ax, None, c_ax)
        if name == "state":                       # (L, B, H, P, N)
            h_ax = "model" if leaf.shape[2] % model_n == 0 else None
            return P(None, b_ax, h_ax, None, None)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(rule, cache)


def ef_specs(param_spec_tree: Tree, fl_ax: str | None = None) -> Tree:
    """Error-feedback memory specs.

    With ``fl_ax`` (the stacked ``(n_fl, *leaf)`` convention of
    :func:`repro.launch.steps.init_ef_tree`): the leading axis is the FL
    device axis, sharded over ``fl_ax``; the per-param dims keep the
    param's own layout shifted right by one.  Without ``fl_ax`` (legacy,
    non-stacked): same layout as params.
    """
    if fl_ax is None:
        return param_spec_tree
    return jax.tree_util.tree_map(
        lambda s: jax.sharding.PartitionSpec(
            fl_ax, *(ax if ax != fl_ax else None for ax in s)),
        param_spec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


def place(tree: Tree, spec_tree: Tree, mesh) -> Tree:
    """device_put a concrete pytree onto its spec'd shardings (jit with
    in_shardings requires committed args to match exactly)."""
    shardings = jax.tree_util.tree_map(
        lambda s: jax.sharding.NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    return jax.device_put(tree, shardings)
