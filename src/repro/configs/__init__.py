"""Config system: ArchConfig + per-architecture modules + registry."""
from .base import ArchConfig
from .registry import ARCH_IDS, get_config, get_smoke_config, list_archs

__all__ = ["ArchConfig", "ARCH_IDS", "get_config", "get_smoke_config",
           "list_archs"]
