"""ArchConfig: one dataclass describing every supported architecture.

Every assigned architecture gets a module ``repro/configs/<id>.py`` exporting
``CONFIG`` (the exact full-size assignment) and ``smoke()`` (a reduced
same-family variant: <=2 layers, d_model <= 512, <= 4 experts) used by the
CPU smoke tests.  ``repro.configs.registry`` maps ``--arch`` ids to modules.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                  # 0 => attention-free
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 => d_model // n_heads
    mlp: str = "swiglu"           # swiglu | gelu (non-gated) | geglu
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    use_rope: bool = True
    tie_embeddings: bool = False
    max_position: int = 131_072   # learned-pos archs use this as table size
    # --- MoE ---
    n_experts: int = 0
    experts_per_tok: int = 0
    d_expert: int = 0             # per-expert hidden dim (defaults to d_ff)
    moe_capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # --- hybrid (zamba2): one SHARED attention block applied every k blocks
    attn_every: int = 0
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500       # whisper: 30 s of 20 ms frames
    # --- modality frontend stub (vlm/audio): prefix embeddings fed directly
    n_prefix_tokens: int = 0
    # --- long-context decode ---
    window: int = 4096            # sliding-window size used by long_500k
    # --- systems knobs ---
    fsdp: bool = False            # additionally shard params over "data"
    optimizer: str = "adamw"      # adamw | sgdm  (sgdm for the 314B MoE)
    remat: bool = True
    attn_q_chunk: int = 512       # query-chunked attention block size
    loss_chunk: int = 1024        # sequence-chunked cross-entropy block
    # §Perf levers (EXPERIMENTS.md): both default ON after hillclimbing;
    # set False to reproduce the paper-faithful/naive baseline rows.
    attn_remat_chunks: bool = True   # recompute attn probs in backward
    attn_seq_shard: bool = True      # context-parallel K/V layout
    dtype: Any = jnp.bfloat16
    source: str = ""              # citation

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def d_exp(self) -> int:
        return self.d_expert or self.d_ff

    @property
    def vocab_padded(self) -> int:
        """Vocab padded to a multiple of 256 so it shards over 16-way model."""
        return math.ceil(self.vocab_size / 256) * 256

    @property
    def is_attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def supports_long_decode(self) -> bool:
        """long_500k: native for ssm/hybrid, sliding-window for the rest."""
        return True  # dense archs use the sliding-window variant (DESIGN §4)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline terms)."""
        d, v = self.d_model, self.vocab_padded
        emb = v * d * (1 if self.tie_embeddings else 2)
        n = emb
        mlp_gate = 3 if self.mlp in ("swiglu", "geglu") else 2

        def attn_params():
            return d * self.n_heads * self.hd + 2 * d * self.n_kv_heads * self.hd \
                + self.n_heads * self.hd * d

        def mlp_params(dff):
            return mlp_gate * d * dff

        def moe_params():
            return d * self.n_experts + self.n_experts * mlp_params(self.d_exp)

        def ssm_params():
            di, ns = self.d_inner, self.ssm_state
            in_proj = d * (2 * di + 2 * ns + self.ssm_heads)
            return in_proj + self.ssm_conv * (di + 2 * ns) + di * d \
                + 2 * self.ssm_heads + di

        if self.arch_type == "ssm":
            n += self.n_layers * (ssm_params() + 2 * d)
        elif self.arch_type == "hybrid":
            n += self.n_layers * (ssm_params() + 2 * d)
            n += attn_params() + mlp_params(self.d_ff) + 2 * d  # shared block
        elif self.arch_type == "moe":
            n += self.n_layers * (attn_params() + moe_params() + 2 * d)
        elif self.arch_type == "audio":
            n += (self.n_layers + self.encoder_layers) * (
                attn_params() + mlp_params(self.d_ff) + 2 * d)
            n += self.n_layers * (attn_params() + d)  # cross-attention
        else:  # dense / vlm
            n += self.n_layers * (attn_params() + mlp_params(self.d_ff) + 2 * d)
        return int(n)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if self.arch_type != "moe":
            return self.param_count()
        mlp_gate = 3 if self.mlp in ("swiglu", "geglu") else 2
        full_moe = self.n_experts * mlp_gate * self.d_model * self.d_exp
        active_moe = self.experts_per_tok * mlp_gate * self.d_model * self.d_exp
        return int(self.param_count() - self.n_layers * (full_moe - active_moe))
