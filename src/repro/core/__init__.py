"""LGC core: layered gradient compression, FL loop, channels, control.

The modules below are bound together by the engine-equivalence ladder
(loop ~ batched == sharded History; docs/ARCHITECTURE.md §1) -- each
module's docstring names the invariant it participates in and the test
that enforces it."""
from repro.launch.compat import ensure_fast_cpu_runtime

# Before anything can touch the backend: on jaxlib 0.4.3x CPU, swap the
# thunk runtime for the legacy one -- while-loop (lax.scan) bodies run ~37x
# faster on small-core hosts (see the function's docstring and
# docs/ARCHITECTURE.md §10).  No-op on other jaxlibs or under
# REPRO_XLA_THUNK_RUNTIME=1.
ensure_fast_cpu_runtime()

from .compressor import (LGCCompressor, flatten_tree, lgc_compress, lgc_layers,
                         lgc_compress_topk, lgc_compress_traced,
                         top_alpha_beta, top_k, tree_size, unflatten_like,
                         wire_bytes)
from .error_feedback import (EF_STORES, DenseEFStore, EFState, Int8EFStore,
                             ServerEFStore, ef_compress, init_ef,
                             make_ef_store)
from .channels import (DEFAULT_CHANNELS, ChannelSpec, DeviceProfile,
                       comm_cost, comp_cost, sample_channels)
from .fl import (ControllerFleet, FLConfig, FLTask, FixedController, History,
                 LGCSimulator, RoundDecision, run_baseline)
from .scenario import (SCENARIOS, DropoutSpec, GaussMarkovSpec,
                       GilbertElliottSpec, HeteroFleetSpec, Scenario,
                       StragglerSpec, get_scenario)
from .controller import (DDPGConfig, DDPGController, FleetDDPG,
                         decode_actions, make_ddpg_controllers,
                         make_fleet_ddpg, obs_dim)
from .audit import audit_simulator, recompute_spend
from .population import (COHORT_SAMPLERS, Population, make_population,
                         make_population_task, run_population, sample_cohort)
from .server import (AGGREGATORS, AggregatorSpec, ServerState, get_aggregator,
                     init_server_state, window_deadline)
from .convergence import ProblemConstants, corollary1_rate, theorem1_bound

__all__ = [
    "LGCCompressor", "flatten_tree", "lgc_compress", "lgc_layers",
    "lgc_compress_topk", "lgc_compress_traced",
    "top_alpha_beta", "top_k", "tree_size", "unflatten_like", "wire_bytes",
    "EF_STORES", "DenseEFStore", "EFState", "Int8EFStore", "ServerEFStore",
    "ef_compress", "init_ef", "make_ef_store",
    "DEFAULT_CHANNELS", "ChannelSpec", "DeviceProfile", "comm_cost",
    "comp_cost", "sample_channels",
    "ControllerFleet", "FLConfig", "FLTask", "FixedController", "History",
    "LGCSimulator", "RoundDecision", "run_baseline",
    "SCENARIOS", "DropoutSpec", "GaussMarkovSpec", "GilbertElliottSpec",
    "HeteroFleetSpec", "Scenario", "StragglerSpec", "get_scenario",
    "DDPGConfig", "DDPGController", "FleetDDPG", "decode_actions",
    "make_ddpg_controllers", "make_fleet_ddpg", "obs_dim",
    "audit_simulator", "recompute_spend",
    "ProblemConstants", "corollary1_rate", "theorem1_bound",
    "COHORT_SAMPLERS", "Population", "make_population",
    "make_population_task", "run_population", "sample_cohort",
    "AGGREGATORS", "AggregatorSpec", "ServerState", "get_aggregator",
    "init_server_state", "window_deadline",
]
