"""Device-axis scaling of the FL simulator: batched engine vs loop engine.

The paper's system claim is scale across many edge devices; the seed
simulator's wall clock grew linearly in M because every round dispatched M
separate jitted SGD steps plus eager per-device compression.  This bench
sweeps the device count for the batched (vmap + scan, one XLA program per
sync window) engine against the reference loop engine and reports

    mode, engine, M, wall_s, rounds/s, device-steps/s, final loss

plus the loop/batched speedup at each M where both ran.  ``--out`` (and
``benchmarks/run.py``) writes the rows as machine-readable BENCH_sim.json
for CI artifact upload, seeding the perf trajectory.

The loop engine is skipped above ``--loop-max-m`` (default 64): at M=256 it
needs tens of minutes, which is exactly the point of the batched engine.
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core import FLConfig, run_baseline
from repro.models.paper_models import make_mnist_task

from .common import emit


def _one(task, cfg, mode: str, engine: str, m: int) -> dict:
    t0 = time.time()
    hist = run_baseline(task, cfg, mode, h=4, engine=engine)
    wall = time.time() - t0
    return {
        "mode": mode, "engine": engine, "m_devices": m,
        "rounds": cfg.rounds, "wall_s": round(wall, 3),
        "rounds_per_s": round(cfg.rounds / wall, 3),
        "device_steps_per_s": round(m * cfg.rounds / wall, 1),
        "final_loss": round(hist.loss[-1], 4),
        "uplink_mb": round(hist.uplink_mb[-1], 4),
    }


def run(ms=(8, 64, 256), rounds: int = 100, loop_max_m: int = 64,
        modes=("lgc",), emit_csv: bool = True) -> dict:
    rows, speedup = [], {}
    for m in ms:
        task = make_mnist_task("lr", m_devices=m,
                               n_train=max(2000, 32 * m))
        cfg = FLConfig(rounds=rounds, eval_every=max(rounds // 4, 1))
        for mode in modes:
            wall = {}
            for engine in ("batched",) if m > loop_max_m else ("loop",
                                                               "batched"):
                row = _one(task, cfg, mode, engine, m)
                rows.append(row)
                wall[engine] = row["wall_s"]
                if emit_csv:
                    emit(f"sim_scaling_{mode}_{engine}_m{m}",
                         row["wall_s"] * 1e6 / rounds,
                         f"rounds_per_s={row['rounds_per_s']};"
                         f"device_steps_per_s={row['device_steps_per_s']};"
                         f"final_loss={row['final_loss']}")
            if "loop" in wall:
                speedup[str(m)] = round(wall["loop"] / wall["batched"], 2)
                if emit_csv:
                    emit(f"sim_scaling_{mode}_speedup_m{m}", 0.0,
                         f"speedup={speedup[str(m)]}x")
    return {"benchmark": "sim_scaling", "task": "lr-mnist",
            "rounds": rounds, "rows": rows, "speedup_loop_over_batched":
            speedup}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ms", default="8,64,256")
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--loop-max-m", type=int, default=64)
    ap.add_argument("--modes", default="lgc")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    res = run(ms=tuple(int(x) for x in args.ms.split(",")),
              rounds=args.rounds, loop_max_m=args.loop_max_m,
              modes=tuple(args.modes.split(",")))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
