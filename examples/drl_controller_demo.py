"""DRL control demo (paper §3): DDPG agents adapt H and the layer-to-channel
allocation as channel conditions shift mid-training.

Halfway through, the 5G channel becomes unreliable and expensive; the
learned controllers shift traffic toward the cheaper channels while the
fixed controller keeps paying.

  PYTHONPATH=src python examples/drl_controller_demo.py
"""
import dataclasses

import jax
import numpy as np

from repro.core import FLConfig, FixedController, LGCSimulator, tree_size
from repro.core.channels import DEFAULT_CHANNELS, ChannelSpec
from repro.core.controller import make_ddpg_controllers
from repro.models.paper_models import make_mnist_task

DEGRADED = (
    DEFAULT_CHANNELS[0],
    DEFAULT_CHANNELS[1],
    ChannelSpec("5G-degraded",
                DEFAULT_CHANNELS[2].energy_mean_j_per_mb * 3,
                DEFAULT_CHANNELS[2].energy_std,
                DEFAULT_CHANNELS[2].bandwidth_mb_s * 0.2,
                DEFAULT_CHANNELS[2].money_per_mb * 4, 0.6),
)


def run_phase(task, ctrls, channels, rounds, mode="lgc"):
    cfg = FLConfig(rounds=rounds, eval_every=rounds // 2, channels=channels)
    sim = LGCSimulator(task, cfg, ctrls, mode=mode)
    h = sim.run()
    return h, sim


def main():
    task = make_mnist_task("lr", m_devices=3, n_train=2000)
    d = tree_size(task.init(jax.random.PRNGKey(0)))

    print("== phase 1: nominal channels (3G/4G/5G) ==")
    ddpg = make_ddpg_controllers(3, d)
    h1, sim1 = run_phase(task, ddpg, DEFAULT_CHANNELS, 80)
    alloc1 = [np.array(c._to_decision(np.zeros(4)).ks) for c in ddpg]
    print(f"  loss {h1.loss[-1]:.3f}, energy {h1.energy_j[-1]:.0f} J")

    print("== phase 2: 5G degraded (3x energy, 4x money, 60% uptime) ==")
    h2, sim2 = run_phase(task, ddpg, DEGRADED, 80)
    print(f"  loss {h2.loss[-1]:.3f}, energy {h2.energy_j[-1]:.0f} J")

    fixed = [FixedController(4, [d // 60, d // 40, d // 40])
             for _ in range(3)]
    h3, _ = run_phase(task, fixed, DEGRADED, 80)
    print(f"== fixed controller under degraded channels: "
          f"energy {h3.energy_j[-1]:.0f} J ==")

    # learned allocation after adaptation
    for m, c in enumerate(ddpg):
        dec = c.act(np.array([1e3, 0.01, 10, 1], np.float32))
        frac = np.array(dec.ks) / sum(dec.ks)
        print(f"  device {m}: H={dec.h} channel split "
              f"3G={frac[0]:.2f} 4G={frac[1]:.2f} 5G={frac[2]:.2f} "
              f"(reward trend {np.mean(c.rewards[-5:]) if c.rewards else 0:+.3f})")
    print("\nThe DDPG agents steer allocation away from the degraded 5G "
          "channel (paper §3 behaviour).")


if __name__ == "__main__":
    main()
