"""End-to-end driver: train the qwen2_100m federated task for a few
hundred sync rounds with LGC gradient compression across 8 simulated FL
devices.

This drives the registry task (``make_task("qwen2_100m", ...)``), i.e. the
real shard_map train step the dry-run lowers for the production mesh,
running on 8 host devices.  Loss must decrease; the script also reports
the LGC wire savings vs a dense exchange.

  PYTHONPATH=src python examples/train_100m_lgc.py --preset smoke --steps 2
  PYTHONPATH=src python examples/train_100m_lgc.py [--steps 300]   # ~128M
"""
import argparse

# The seed version did os.environ.setdefault("XLA_FLAGS", ...), which is a
# no-op whenever XLA_FLAGS is inherited (e.g. a CI lane exporting only
# --xla_cpu_use_thunk_runtime=false) -- the mesh build then dies with
# "Number of devices 1 must be >= 8".  force_host_device_count rewrites the
# device-count flag while preserving the rest, and composes with
# ensure_fast_cpu_runtime regardless of call order (tests/test_compat.py).
from repro.launch.compat import force_host_device_count

force_host_device_count(8)

from repro.models.paper_models import make_task  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    # defaults sized for the 1-core CPU container; on a real pod raise all
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preset", default="full", choices=["full", "smoke"])
    ap.add_argument("--batch-per-device", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--local-lr", type=float, default=3e-3)
    ap.add_argument("--sparsity", default="0.01,0.02,0.02")
    ap.add_argument("--aggregate", default="sparse_gather",
                    choices=["dense_masked", "sparse_gather",
                             "bucket_sparse", "none"])
    ap.add_argument("--backend", default="exact",
                    choices=["exact", "pallas"],
                    help="pallas = fused Pallas compression kernels on the "
                         ">=PALLAS_MIN_ELEMS dense-path leaves (interpret "
                         "mode on CPU: parity, not speed)")
    ap.add_argument("--scenario", default=None,
                    help="e.g. gilbert_flaky for lossy multi-channel uplinks")
    args = ap.parse_args()

    task = make_task("qwen2_100m", m_devices=8, scenario=args.scenario,
                     preset=args.preset,
                     sparsity=tuple(float(x)
                                    for x in args.sparsity.split(",")),
                     aggregate=args.aggregate, local_steps=args.local_steps,
                     local_lr=args.local_lr,
                     batch_per_device=args.batch_per_device, seq=args.seq,
                     backend=args.backend)
    n = task.param_count()
    print(f"{task.name}: {n/1e6:.1f}M params, {task.m_devices} FL devices, "
          f"H={args.local_steps} local steps, sparsity {args.sparsity}, "
          f"aggregate {args.aggregate}")

    out = task.run(args.steps, log_every=20)
    losses = out["losses"]

    from repro.launch.steps import lgc_wire_bytes_per_round  # jax now warm
    import jax
    from repro.models import transformer as tf
    import jax.numpy as jnp
    p = jax.eval_shape(lambda k: tf.init_params(task.arch, k),
                       jax.ShapeDtypeStruct((2,), jnp.uint32))
    wire = lgc_wire_bytes_per_round(p, task.step_cfg)
    dense_mb = wire["none"] / 1e6
    lgc_mb = max(wire[args.aggregate], 1) / 1e6
    print(f"\nwire per round per device: dense {dense_mb:.1f} MB vs "
          f"LGC {lgc_mb:.1f} MB  ({dense_mb/lgc_mb:.1f}x reduction)")
    if args.steps >= 20:
        assert losses[-1] < losses[0], "loss must decrease"
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"over {args.steps} rounds "
          f"({out['device_steps_per_s']:.2f} device-steps/s)")


if __name__ == "__main__":
    main()
