"""Sharded-engine suite: the batched LGC engine's device axis partitioned
over a real mesh via shard_map (repro.core.fl_batched.ShardedEngine).

Every test adapts to however many host devices are present, so the suite is
meaningful in the plain CI lane (1 device -- a degenerate 1-way mesh still
exercises the shard_map + all_gather program) and decisive in the
test-sharded lane, which forces an 8-way host mesh with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

The invariant under test: only the server aggregation crosses the mesh's FL
axis, and with the default ``server_reduce="gather"`` the History is
BIT-identical to the unsharded batched engine -- same floats, not allclose.
"""
import jax
import numpy as np
import pytest

from repro.core import (FLConfig, FixedController, LGCSimulator,
                        make_fleet_ddpg, run_baseline, tree_size)
from repro.core.fl_batched import ShardedEngine
from repro.launch.mesh import fl_axis_name, make_host_mesh
from repro.models.paper_models import make_mnist_task

N_DEV = len(jax.devices())


@pytest.fixture(scope="module")
def task8():
    return make_mnist_task("lr", m_devices=8, n_train=2000)


@pytest.fixture(scope="module")
def task16():
    return make_mnist_task("lr", m_devices=16, n_train=2400)


class TestShardedEquivalence:
    """Sharded vs unsharded batched engine: bit-identical trajectories."""

    @pytest.mark.parametrize("mode", ["lgc", "fedavg", "topk", "lgc_q8"])
    def test_history_bit_identical(self, task8, mode):
        cfg = FLConfig(rounds=20, eval_every=10)
        h_bat = run_baseline(task8, cfg, mode, h=4, engine="batched")
        h_sh = run_baseline(task8, cfg, mode, h=4, engine="sharded")
        assert h_sh.asdict() == h_bat.asdict()

    def test_heterogeneous_gaps_bit_identical(self, task16):
        """Different per-device H means ragged sync sets: each window's
        sync_mask splits differently across shards, and the gathered server
        reduce must still reproduce the unsharded sum exactly."""
        cfg = FLConfig(rounds=25, eval_every=8, max_gap=6)

        def ctrls():
            return [FixedController(2 + (m % 5), [200, 300, 400])
                    for m in range(16)]
        h_bat = LGCSimulator(task16, cfg, ctrls(), mode="lgc",
                             engine="batched").run()
        h_sh = LGCSimulator(task16, cfg, ctrls(), mode="lgc",
                            engine="sharded").run()
        assert h_sh.asdict() == h_bat.asdict()

    def test_ddpg_fleet_bit_identical(self, task16):
        """The full control plane -- FleetDDPG acting, training and being
        rewarded through the batched TAG_REWARD eval -- on the sharded
        engine, bit-identical to unsharded."""
        d = tree_size(task16.init(jax.random.PRNGKey(0)))
        cfg = FLConfig(rounds=25, eval_every=8, max_gap=6)
        h_bat = LGCSimulator(task16, cfg, make_fleet_ddpg(16, d), mode="lgc",
                             engine="batched").run()
        h_sh = LGCSimulator(task16, cfg, make_fleet_ddpg(16, d), mode="lgc",
                            engine="sharded").run()
        assert h_sh.asdict() == h_bat.asdict()

    def test_pallas_backend_bit_identical(self, task8):
        cfg = FLConfig(rounds=16, eval_every=8)
        h_bat = run_baseline(task8, cfg, "lgc", h=4, engine="batched",
                             backend="pallas")
        h_sh = run_baseline(task8, cfg, "lgc", h=4, engine="sharded",
                            backend="pallas")
        assert h_sh.asdict() == h_bat.asdict()

    def test_psum_reduce_is_close_not_bitwise(self, task8):
        """server_reduce="psum" crosses only O(d) partial sums per link; the
        reassociated float reduction tracks the gathered reduce to ~1e-5."""
        cfg = FLConfig(rounds=20, eval_every=10)
        h_bat = run_baseline(task8, cfg, "lgc", h=4, engine="batched")
        h_ps = run_baseline(task8, cfg, "lgc", h=4, engine="sharded",
                            server_reduce="psum")
        np.testing.assert_allclose(h_ps.loss, h_bat.loss, atol=1e-4)
        np.testing.assert_allclose(h_ps.uplink_mb, h_bat.uplink_mb,
                                   atol=1e-4)


class TestShardedValidation:
    def test_state_is_actually_sharded(self, task8):
        """The engine's stacked per-device state must live partitioned over
        the FL axis, one M/D block per mesh device -- not replicated."""
        ctrls = [FixedController(4, [200, 300, 400]) for _ in range(8)]
        sim = LGCSimulator(task8, FLConfig(rounds=8), ctrls, mode="lgc",
                           engine="sharded")
        eng = ShardedEngine(sim)
        assert eng.n_shards == N_DEV
        shard_devs = {s.device for s in eng.ef.addressable_shards}
        assert len(shard_devs) == N_DEV
        rows = {s.data.shape[0] for s in eng.ef.addressable_shards}
        assert rows == {8 // N_DEV}

    def test_indivisible_m_raises(self):
        task = make_mnist_task("lr", m_devices=3, n_train=600)
        if N_DEV == 1:
            pytest.skip("every M divides a 1-way mesh")
        with pytest.raises(ValueError, match="do not divide"):
            run_baseline(task, FLConfig(rounds=4), "lgc", engine="sharded")

    def test_bad_server_reduce_raises(self, task8):
        with pytest.raises(ValueError, match="server_reduce"):
            run_baseline(task8, FLConfig(rounds=4), "lgc", engine="sharded",
                         server_reduce="allgather")

    def test_make_host_mesh_indivisible_raises(self):
        with pytest.raises(ValueError) as exc:
            make_host_mesh(N_DEV, model=3 if N_DEV % 3 else N_DEV + 1)
        assert "mesh" in str(exc.value) and str(N_DEV) in str(exc.value)

    def test_fl_axis_name_host_mesh(self):
        assert fl_axis_name(make_host_mesh()) == "data"
