"""Mamba2 SSD (state-space duality) block [arXiv:2405.21060].

Chunked-scan formulation: the sequence is split into chunks of length Q;
within a chunk the output is an attention-like masked matmul (MXU work),
across chunks a single recurrent state (H, P, N) is propagated with
``jax.lax.scan`` -- the TPU-native layout of the SSD algorithm (matmuls
dominate, the scan is O(S/Q) steps).

Shapes: x (B, S, D); heads H = d_inner/head_dim, head dim P, state N.
``ssd_step`` is the O(1) decode recurrence; test_models.py asserts the
chunked scan and the step recurrence produce identical outputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import rmsnorm

Array = jax.Array


def ssm_init(key: Array, cfg, dtype) -> dict:
    d, di, ns, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    d_in_proj = 2 * di + 2 * ns + nh      # z, x, B, C, dt
    return {
        "in_proj": (jax.random.normal(ks[0], (d, d_in_proj)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di + 2 * ns))
                   * 0.2).astype(dtype),
        "conv_b": jnp.zeros((di + 2 * ns,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": (jax.random.normal(ks[3], (di, d)) * di ** -0.5
                     ).astype(dtype),
    }


def _split_in_proj(zxbcdt: Array, cfg):
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * ns]
    dt = zxbcdt[..., di + di + 2 * ns:]
    assert dt.shape[-1] == nh
    return z, xbc, dt


def _causal_conv(xbc: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv over time. xbc: (B,S,C); w: (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def ssd_chunked(x: Array, dt: Array, a_log: Array, b_mat: Array, c_mat: Array,
                chunk: int) -> tuple[Array, Array]:
    """Core SSD scan.

    x:  (B, S, H, P)   inputs per head
    dt: (B, S, H)      softplus'd timestep
    a_log: (H,)        -A = exp(a_log)
    b_mat, c_mat: (B, S, N)  input/output projections (single group)
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    da = dt * (-jnp.exp(a_log))[None, None, :]            # (B,S,H) log-decay
    xr = x.reshape(bsz, nc, q, h, p)
    dtr = dt.reshape(bsz, nc, q, h)
    dar = da.reshape(bsz, nc, q, h)
    br = b_mat.reshape(bsz, nc, q, n)
    cr = c_mat.reshape(bsz, nc, q, n)

    cum = jnp.cumsum(dar, 2)                               # (B,nc,Q,H)
    seg_total = cum[:, :, -1]                              # (B,nc,H)

    # ----- intra-chunk (attention-like, strictly causal + diagonal) -------
    # L[i,j] = exp(cum_i - cum_j) for i >= j.  Mask BEFORE the exp: masking
    # after produces 0*inf = NaN in the backward pass (upper-tri diff > 0).
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,Q,H)
    tril = jnp.tril(jnp.ones((q, q), bool))
    diff = jnp.where(tril[None, None, :, :, None], diff, -1e30)
    l_mat = jnp.exp(diff)
    cb = jnp.einsum("bcin,bcjn->bcij", cr, br)             # (B,nc,Q,Q)
    w_ij = cb[..., None] * l_mat * dtr[:, :, None, :, :]   # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp",
                         w_ij, xr.astype(jnp.float32))

    # ----- chunk states ----------------------------------------------------
    decay_to_end = jnp.exp(seg_total[:, :, None, :] - cum)  # (B,nc,Q,H)
    state_contrib = jnp.einsum(
        "bcqh,bcqn,bcqhp->bchpn",
        (dtr * decay_to_end), br, xr.astype(jnp.float32))   # per-chunk state

    def scan_fn(h_prev, inp):
        contrib, seg = inp                                  # (B,H,P,N),(B,H)
        h_new = h_prev * jnp.exp(seg)[:, :, None, None] + contrib
        return h_new, h_prev

    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    h_final, h_prevs = jax.lax.scan(
        scan_fn, h0,
        (jnp.swapaxes(state_contrib, 0, 1), jnp.swapaxes(seg_total, 0, 1)))
    h_prevs = jnp.swapaxes(h_prevs, 0, 1)                   # (B,nc,H,P,N)

    # ----- inter-chunk contribution ---------------------------------------
    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", cr, h_prevs) \
        * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y.astype(x.dtype), h_final


def ssd_step(x: Array, dt: Array, a_log: Array, b_vec: Array, c_vec: Array,
             state: Array) -> tuple[Array, Array]:
    """Single-token recurrence (decode).

    x: (B,H,P); dt: (B,H); b_vec,c_vec: (B,N); state: (B,H,P,N).
    h' = exp(dt*A) h + dt * x (outer) B;   y = h' C
    """
    da = jnp.exp(dt * (-jnp.exp(a_log))[None, :])          # (B,H)
    xf = x.astype(jnp.float32)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xf, b_vec)
    h_new = state * da[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", h_new, c_vec)
    return y.astype(x.dtype), h_new


def ssm_block(x: Array, p: dict, cfg) -> tuple[Array, Array]:
    """Full mamba2 block, training mode. x: (B,S,D) -> (y, final_state)."""
    di, ns, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = _split_in_proj(zxbcdt, cfg)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :di]
    b_mat = xbc[..., di:di + ns].astype(jnp.float32)
    c_mat = xbc[..., di + ns:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    bsz, s, _ = x.shape
    xh = xs.reshape(bsz, s, nh, hp)
    y, h_final = ssd_chunked(xh, dt, p["a_log"], b_mat, c_mat, cfg.ssm_chunk)
    y = y + xh.astype(y.dtype) * p["d_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(bsz, s, di)
    y = rmsnorm(y, p["norm_scale"]) * jax.nn.silu(z)
    return y @ p["out_proj"], h_final


class SSMCache:
    """Decode-time cache: conv tail + recurrent state (NamedTuple-free for
    pytree simplicity -- plain dict used in the model code)."""


def ssm_block_step(x: Array, p: dict, cfg, conv_tail: Array, state: Array
                   ) -> tuple[Array, Array, Array]:
    """One decode token. x: (B,1,D); conv_tail: (B,K-1,C); state (B,H,P,N)."""
    di, ns, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = _split_in_proj(zxbcdt, cfg)
    # causal conv over [tail, current]
    hist = jnp.concatenate([conv_tail, xbc], 1)            # (B,K,C)
    kk = p["conv_w"].shape[0]
    conv_out = sum(hist[:, i] * p["conv_w"][i] for i in range(kk))
    xbc1 = jax.nn.silu(conv_out + p["conv_b"])             # (B,C)
    new_tail = hist[:, 1:]
    xs = xbc1[..., :di]
    b_vec = xbc1[..., di:di + ns].astype(jnp.float32)
    c_vec = xbc1[..., di + ns:].astype(jnp.float32)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    xh = xs.reshape(-1, nh, hp)
    y, state_new = ssd_step(xh, dt1, p["a_log"], b_vec, c_vec, state)
    y = y + xh.astype(y.dtype) * p["d_skip"][None, :, None].astype(y.dtype)
    y = y.reshape(x.shape[0], 1, di)
    y = rmsnorm(y, p["norm_scale"]) * jax.nn.silu(z)
    return y @ p["out_proj"], new_tail, state_new
