"""Production meshes.

Single pod:  (16, 16)      over ("data", "model")      -- 256 chips (v5e pod)
Multi-pod:   (2, 16, 16)   over ("pod", "data", "model") -- 512 chips

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module does not touch jax device state; the dry-run sets
``--xla_force_host_platform_device_count=512`` before any jax import.

On the multi-pod mesh the "pod" axis is the slow (DCN-class) link: it is the
FL-device axis for LGC -- each pod is one paper "edge device", and LGC
compresses exactly the traffic that crosses it (DESIGN.md §3).
"""
from __future__ import annotations

import jax

from .compat import make_mesh, set_mesh  # noqa: F401  (set_mesh re-exported)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(n_devices: int | None = None, model: int = 1
                   ) -> jax.sharding.Mesh:
    """Small mesh over the actually-present (host) devices, for examples
    and integration tests."""
    n = n_devices or len(jax.devices())
    if model < 1 or n % model != 0:
        raise ValueError(
            f"make_host_mesh: {n} devices do not factor into a "
            f"(data={n}/{model}, model={model}) mesh; n_devices must be a "
            f"positive multiple of model")
    return make_mesh((n // model, model), ("data", "model"))


def fl_axis_name(mesh: jax.sharding.Mesh) -> str:
    """The slow axis LGC compresses over: 'pod' when present, else 'data'."""
    return "pod" if "pod" in mesh.axis_names else "data"
