"""Zamba2-1.2B [arXiv:2411.15242] -- hybrid: Mamba2 backbone with ONE shared
attention(+MLP) block applied every 6 mamba blocks (weight sharing is the
zamba trick; each application site keeps its own KV cache at decode)."""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", arch_type="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32_000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=128,
    attn_every=6,
    mlp="swiglu", norm="rmsnorm",
    source="arXiv:2411.15242",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="zamba2-smoke", n_layers=4, d_model=128, n_heads=4,
        n_kv_heads=4, d_ff=256, ssm_state=16, ssm_head_dim=32, ssm_chunk=16,
        attn_every=2, vocab_size=512, remat=False, attn_q_chunk=64)
