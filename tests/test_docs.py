"""Documented entry points can't rot: every ```python block in README.md
must execute (the CI docs lane runs this module plus examples/quickstart.py
under the smoke budget).

Snippets run in one shared namespace, in order, so later blocks may build
on earlier imports -- keep README snippets small enough that the whole file
executes in about a minute on CPU."""
import pathlib
import re

README = pathlib.Path(__file__).resolve().parent.parent / "README.md"
_BLOCK_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _snippets():
    return _BLOCK_RE.findall(README.read_text())


def test_readme_has_python_snippets():
    assert len(_snippets()) >= 2       # scenario + task zoo quickstarts


def test_readme_snippets_execute():
    ns: dict = {"__name__": "__readme__"}
    for i, snippet in enumerate(_snippets()):
        try:
            exec(compile(snippet, f"README.md#snippet{i}", "exec"), ns)
        except Exception as e:          # pragma: no cover - failure path
            raise AssertionError(
                f"README snippet {i} failed: {e}\n---\n{snippet}") from e


def test_quickstart_example_importable():
    """The docs lane executes examples/quickstart.py as a script; here we
    only pin that it stays importable with an argparse main()."""
    import importlib.util
    path = README.parent / "examples" / "quickstart.py"
    spec = importlib.util.spec_from_file_location("quickstart", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    assert callable(mod.main)
