"""Scenario zoo sweep: accuracy-vs-cost of fixed vs DDPG control across the
named-scenario registry (repro.core.scenario.SCENARIOS).

The paper's premise is that learned control pays off when the environment is
*dynamic*; the seed benchmarks only ever ran the memoryless "static" model.
This bench runs every registry scenario -- Gauss-Markov bandwidth,
Gilbert-Elliott burst availability, flaky/straggler devices, Dirichlet data
skew -- under (a) the fixed LGC controller and (b) a DDPG fleet, on the
batched engine, and records final accuracy next to the resource spend
(energy / money / wall time / uplink).  Rows land in ``BENCH_scenarios.json``
via ``benchmarks/run.py`` (CI uploads it as artifact).
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.core import (SCENARIOS, FLConfig, FleetDDPG, LGCSimulator,
                        run_baseline, tree_size)
from repro.core.controller import DDPGConfig
from repro.models.paper_models import make_mnist_task

from .common import emit


def _row(scenario: str, controller: str, hist, wall: float, m: int,
         rounds: int, **extra) -> dict:
    return {
        "scenario": scenario, "controller": controller, "m_devices": m,
        "rounds": rounds, "wall_s": round(wall, 3),
        "final_loss": round(hist.loss[-1], 4),
        "final_accuracy": round(hist.accuracy[-1], 4),
        "energy_j": round(hist.energy_j[-1], 2),
        "money": round(hist.money[-1], 4),
        "time_s": round(hist.time_s[-1], 2),
        "uplink_mb": round(hist.uplink_mb[-1], 4),
        **extra,
    }


def run(scenarios=None, m: int = 8, rounds: int = 60, n_train: int = 2000,
        emit_csv: bool = True) -> dict:
    names = list(scenarios or SCENARIOS)
    rows = []
    for name in names:
        task = make_mnist_task("lr", m_devices=m, n_train=n_train,
                               scenario=name)
        cfg = FLConfig(rounds=rounds, eval_every=max(rounds // 4, 1),
                       scenario=name)
        t0 = time.time()
        h_fix = run_baseline(task, cfg, "lgc", h=4, engine="batched")
        rows.append(_row(name, "fixed", h_fix, time.time() - t0, m, rounds))
        d = tree_size(task.init(jax.random.PRNGKey(0)))
        # batch_size=4 so the replay buffer warms within the bench budget
        # (a device inserts one transition per sync; the default batch of 64
        # would leave the fleet untrained and benchmark exploration noise)
        fleet = FleetDDPG(m, DDPGConfig(
            k_total_max=max(3, int(d * 0.05)), batch_size=4, seed=0))
        t0 = time.time()
        h_drl = LGCSimulator(task, cfg, fleet, mode="lgc",
                             engine="batched").run()
        train_steps = int(fleet._n_train.sum())
        assert train_steps > 0, f"DDPG never trained on {name}; raise rounds"
        rows.append(_row(name, "ddpg", h_drl, time.time() - t0, m, rounds,
                         ddpg_train_steps=train_steps))
        if emit_csv:
            emit(f"scenario_{name}",
                 (rows[-2]["wall_s"] + rows[-1]["wall_s"]) * 1e6 / rounds,
                 f"fixed_acc={rows[-2]['final_accuracy']};"
                 f"ddpg_acc={rows[-1]['final_accuracy']};"
                 f"fixed_energy={rows[-2]['energy_j']};"
                 f"ddpg_energy={rows[-1]['energy_j']}")
    return {"m_devices": m, "rounds": rounds, "rows": rows}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--m", type=int, default=8)
    ap.add_argument("--scenarios", default=None,
                    help="comma-separated registry names (default: all)")
    ap.add_argument("--out", default="BENCH_scenarios.json")
    args = ap.parse_args()
    names = args.scenarios.split(",") if args.scenarios else None
    res = run(scenarios=names, m=args.m, rounds=args.rounds)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
