"""Pallas TPU kernels: magnitude statistics for histogram-Top_k selection.

TPU-native replacement for the global sort behind Top_k (must match the
:mod:`repro.kernels.ref` oracles bit-exactly --
tests/test_kernels.py::TestMaxAbs/TestHistogram):

  pass 1: ``maxabs``    -- blocked max-|x| reduction
  pass 2: ``histogram`` -- blocked 256-bin magnitude histogram
  host    : thresholds from the descending histogram CDF (256 scalars)

Both kernels view the flat gradient as a (rows, 128)-shaped matrix -- the
TPU vector-lane layout -- and tile over row blocks held in VMEM.  The
histogram scatter is expressed as a one-hot contraction (bins x lanes),
which maps onto the VPU instead of a serial scatter.

Grid iteration on TPU is sequential per core, so both kernels accumulate
into their (revisited) output block across grid steps; ``@pl.when(step==0)``
initialises it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_BINS = 256
LANES = 128


def _maxabs_kernel(x_ref, o_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
    block_max = jnp.max(jnp.abs(x_ref[...].astype(jnp.float32)))
    o_ref[0, 0] = jnp.maximum(o_ref[0, 0], block_max)


def _hist_kernel(x_ref, maxabs_ref, o_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
    a = jnp.abs(x_ref[...].astype(jnp.float32))        # (rows, 128)
    m = maxabs_ref[0, 0]
    scale = jnp.where(m > 0, N_BINS / m, 0.0)
    bins = jnp.clip((a * scale).astype(jnp.int32), 0, N_BINS - 1)
    # one-hot contraction: counts[b] = sum_ij [bins_ij == b]
    bin_ids = jax.lax.broadcasted_iota(jnp.int32, (N_BINS, 1, 1), 0)
    onehot = (bins[None, :, :] == bin_ids).astype(jnp.int32)
    o_ref[...] += jnp.sum(onehot, axis=(1, 2))[None, :]


def _as_rows(x: jax.Array, block_rows: int) -> tuple[jax.Array, int, int]:
    """Pad flat x with zeros to a (rows,128) matrix, rows % block_rows == 0."""
    d = x.shape[0]
    per_block = block_rows * LANES
    padded = (d + per_block - 1) // per_block * per_block
    pad = padded - d
    xr = jnp.pad(x, (0, pad)).reshape(-1, LANES)
    return xr, xr.shape[0] // block_rows, pad


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def maxabs(x: jax.Array, *, block_rows: int = 64,
           interpret: bool = True) -> jax.Array:
    """max |x| over a flat vector. Returns (1,1) f32."""
    xr, n_blocks, _ = _as_rows(x, block_rows)
    return pl.pallas_call(
        _maxabs_kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(xr)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def histogram(x: jax.Array, maxabs_val: jax.Array, *, block_rows: int = 64,
              interpret: bool = True) -> jax.Array:
    """256-bin |x| histogram; padding-corrected. Returns (256,) int32."""
    xr, n_blocks, pad = _as_rows(x, block_rows)
    counts = pl.pallas_call(
        _hist_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, N_BINS), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, N_BINS), jnp.int32),
        interpret=interpret,
    )(xr, maxabs_val.reshape(1, 1))[0]
    return counts.at[0].add(-pad)  # zero padding lands in bin 0


def thresholds_from_counts(counts: jax.Array, maxabs_val: jax.Array,
                           cum_ks: jax.Array) -> jax.Array:
    """Host-side (tiny): per-layer thresholds from the histogram CDF.

    Identical semantics to ref.hist_thresholds.
    """
    desc = jnp.cumsum(counts[::-1])[::-1]
    bin_w = maxabs_val.reshape(()) / N_BINS

    def one(k):
        ok = desc >= k
        b = jnp.where(jnp.any(ok),
                      jnp.max(jnp.where(ok, jnp.arange(N_BINS), -1)), 0)
        return b.astype(jnp.float32) * bin_w
    return jax.vmap(one)(cum_ks).astype(jnp.float32)
