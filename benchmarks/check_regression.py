"""Bench regression gate: fail CI when the batched engine slows down.

Compares the batched-engine ``device_steps_per_s`` rows of a freshly
generated BENCH_sim.json against the committed BENCH_baseline.json and exits
nonzero when any matching (mode, engine, M) row regresses more than
``--tolerance`` (default 30%).  Rows present on only one side are reported
but never fail the gate (new sweeps should not need a baseline update to
land), and faster-than-baseline rows print so improvements are visible in
the CI log.

The committed baseline was measured on a 2-core container -- slower than the
CI runners -- so the gate only trips on real order-of-magnitude regressions
(a lost jit, an accidental O(M) host loop), not runner jitter.  Refresh it
with:

    python -m benchmarks.run --smoke && cp BENCH_sim.json BENCH_baseline.json
"""
from __future__ import annotations

import argparse
import json
import sys


def check(baseline: dict, current: dict, tolerance: float,
          engines: tuple[str, ...] = ("batched",)) -> list[str]:
    base_rows = {(r["mode"], r["engine"], r["m_devices"]): r
                 for r in baseline["rows"]}
    seen, failures = set(), []
    for r in current["rows"]:
        if r["engine"] not in engines:
            continue
        key = (r["mode"], r["engine"], r["m_devices"])
        seen.add(key)
        b = base_rows.get(key)
        if b is None:
            print(f"  new row (no baseline): {key}  "
                  f"{r['device_steps_per_s']:.1f} device-steps/s")
            continue
        floor = b["device_steps_per_s"] * (1.0 - tolerance)
        ratio = r["device_steps_per_s"] / b["device_steps_per_s"]
        verdict = "ok" if r["device_steps_per_s"] >= floor else "REGRESSED"
        print(f"  {verdict:>9}: {key}  baseline "
              f"{b['device_steps_per_s']:.1f} -> current "
              f"{r['device_steps_per_s']:.1f} device-steps/s  "
              f"({ratio:.2f}x, floor {floor:.1f})")
        if verdict == "REGRESSED":
            failures.append(f"{key}: {ratio:.2f}x of baseline")
    for key in set(base_rows) - seen:
        if base_rows[key]["engine"] in engines:
            print(f"  baseline row missing from current run: {key}")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--current", default="BENCH_sim.json")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed fractional drop in device_steps_per_s")
    args = ap.parse_args()
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)
    print(f"bench regression gate: tolerance {args.tolerance:.0%} "
          f"({args.baseline} vs {args.current})")
    failures = check(baseline, current, args.tolerance)
    if failures:
        print("bench regression gate FAILED:")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print("bench regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
