"""Property suite for the controller action space (ARCHITECTURE.md §13).

``decode_actions`` is the single point where raw policy outputs become
simulator decisions, for the per-agent controllers, the fleet, and both
action spaces -- so its invariants are load-bearing for every engine:

* per-device budget clamp: ``1 <= ks_{m,c}`` and ``sum_c ks_{m,c} <=
  max(C, k_total_max)`` row by row, for ANY raw action tensor;
* local-step bounds: ``1 <= h_m <= h_max``; with a battery the energy clamp
  ``h_m <= 1 + floor(battery_m * (h_max - 1))`` (zero battery pins h_m = 1);
* determinism and shape stability: decoding a stacked (M, 1+C) batch row by
  row equals decoding it at once, for M in {1, 8, 64}.

Plus the satellite fix: ``DDPGConfig.state_dim`` is validated against the
observation width the simulator actually builds
(:func:`repro.core.controller.obs_dim`), and :class:`FleetDDPG` refuses
misaligned state vectors with both shapes in the error.
"""
import numpy as np
import pytest

from repro.core import DDPGConfig, FLConfig, FleetDDPG, LGCSimulator
from repro.core.controller import (BATTERY_COL, PROFILE_DIM, SPEND_DIM,
                                   decode_actions, make_fleet_ddpg, obs_dim)
from repro.core.fl import FixedController

from _hypothesis_compat import given, settings, st  # hypothesis or fallback


def _unit(i: int) -> float:
    """Map an int draw to [-1, 1] (the tanh action range)."""
    return max(-1.0, min(1.0, i / 1000.0))


@st.composite
def action_batches(draw):
    """(a (M, 1+C), h_max, k_total_max, battery (M,) | None)."""
    m = draw(st.integers(min_value=1, max_value=16))
    n_ch = draw(st.integers(min_value=1, max_value=5))
    h_max = draw(st.integers(min_value=1, max_value=12))
    k_total = draw(st.integers(min_value=0, max_value=4000))
    flat = draw(st.lists(st.integers(min_value=-1500, max_value=1500),
                         min_size=m * (1 + n_ch), max_size=m * (1 + n_ch)))
    a = np.array([_unit(v) for v in flat], np.float64).reshape(m, 1 + n_ch)
    with_batt = draw(st.booleans())
    if with_batt:
        bl = draw(st.lists(st.integers(min_value=0, max_value=1000),
                           min_size=m, max_size=m))
        battery = np.array(bl, np.float64) / 1000.0
    else:
        battery = None
    return a, h_max, k_total, battery


class TestDecodeActionProperties:
    @given(action_batches())
    @settings(max_examples=60, deadline=None)
    def test_budget_and_step_clamps(self, case):
        a, h_max, k_total, battery = case
        n_ch = a.shape[1] - 1
        h, ks = decode_actions(a, h_max, k_total, n_ch, battery=battery)
        assert h.shape == (a.shape[0],) and ks.shape == (a.shape[0], n_ch)
        assert np.all(h >= 1) and np.all(h <= h_max)
        # per-device budget clamp, row by row
        assert np.all(ks >= 1)
        assert np.all(ks.sum(-1) <= max(n_ch, k_total))
        if battery is not None:
            cap = 1 + np.floor(np.clip(battery, 0, 1) * (h_max - 1))
            assert np.all(h <= cap)

    @given(action_batches())
    @settings(max_examples=30, deadline=None)
    def test_deterministic(self, case):
        a, h_max, k_total, battery = case
        n_ch = a.shape[1] - 1
        h1, ks1 = decode_actions(a, h_max, k_total, n_ch, battery=battery)
        h2, ks2 = decode_actions(a, h_max, k_total, n_ch, battery=battery)
        np.testing.assert_array_equal(h1, h2)
        np.testing.assert_array_equal(ks1, ks2)

    def test_zero_battery_pins_floor(self):
        """A drained device never computes more than the mandatory step,
        even when its policy saturates the action."""
        rng = np.random.default_rng(0)
        for _ in range(20):
            a = rng.uniform(-1, 1, size=(8, 4))
            a[:, 0] = 1.0                      # policy wants h = h_max
            h, _ = decode_actions(a, 8, 500, 3, battery=np.zeros(8))
            assert np.all(h == 1)

    @pytest.mark.parametrize("m", [1, 8, 64])
    def test_shape_stable_batch_equals_rowwise(self, m):
        """Decoding a stacked batch == decoding each row alone (the fleet
        and the per-agent list must make identical decisions)."""
        rng = np.random.default_rng(m)
        a = rng.uniform(-1, 1, size=(m, 4))
        battery = rng.uniform(0, 1, size=m)
        h, ks = decode_actions(a, 8, 320, 3, battery=battery)
        assert h.shape == (m,) and ks.shape == (m, 3)
        for i in range(m):
            hi, ksi = decode_actions(a[i], 8, 320, 3, battery=battery[i:i + 1])
            assert hi == h[i]
            np.testing.assert_array_equal(ksi, ks[i])


class TestObservationWidth:
    def test_obs_dim_layout(self):
        assert obs_dim(3, "shared") == SPEND_DIM == 4
        assert obs_dim(3, "per_device") == SPEND_DIM + PROFILE_DIM + 3 == 9
        assert BATTERY_COL == SPEND_DIM
        with pytest.raises(ValueError, match="action_space"):
            obs_dim(3, "layered")

    def test_state_dim_validated_at_construction(self):
        """The satellite fix: a state_dim that disagrees with the simulator's
        observation builder raises with BOTH widths, instead of silently
        training a misaligned replay buffer."""
        with pytest.raises(ValueError, match=r"state_dim=4.*width 9"):
            DDPGConfig(state_dim=4, action_space="per_device")
        with pytest.raises(ValueError, match=r"state_dim=9.*width 4"):
            DDPGConfig(state_dim=9, action_space="shared")
        # and the matching widths construct fine
        DDPGConfig(state_dim=4, action_space="shared")
        DDPGConfig(state_dim=9, action_space="per_device")

    def test_fleet_rejects_misaligned_states(self):
        fleet = make_fleet_ddpg(2, 1000, action_space="per_device")
        assert fleet.cfg.state_dim == 9
        with pytest.raises(ValueError, match=r"width 4.*state_dim=9"):
            fleet.act(np.zeros((2, 4), np.float32))
        with pytest.raises(ValueError, match=r"width 4.*state_dim=9"):
            fleet.observe(np.zeros(2), np.zeros((2, 4), np.float32))

    def test_simulator_rejects_unknown_action_space(self):
        from repro.models.paper_models import make_mnist_task
        task = make_mnist_task("lr", m_devices=2, n_train=200)
        cfg = FLConfig(rounds=2, action_space="layered")
        with pytest.raises(ValueError, match="action_space"):
            LGCSimulator(task, cfg, [FixedController(2, [4, 2, 2])] * 2)

    def test_mismatched_fleet_and_config_raise(self):
        """A shared-width fleet driving a per_device simulator trips the
        width check at the first act (and vice versa)."""
        from repro.models.paper_models import make_mnist_task
        task = make_mnist_task("lr", m_devices=2, n_train=200)
        fleet = make_fleet_ddpg(2, 1000, action_space="shared")
        cfg = FLConfig(rounds=4, action_space="per_device")
        sim = LGCSimulator(task, cfg, fleet)
        with pytest.raises(ValueError, match="state_dim"):
            sim.run()


class TestPerDeviceFleetActs:
    def test_battery_clamps_fleet_decisions(self):
        """A per_device fleet given zero-battery raw states never picks
        h > 1, whatever its (random-init) policies say."""
        fleet = make_fleet_ddpg(4, 2000, action_space="per_device")
        states = np.ones((4, 9), np.float32)
        states[:, BATTERY_COL] = 0.0
        h, ks = fleet.act(states)
        assert np.all(h == 1)
        assert ks.shape == (4, 3)
        full = np.ones((4, 9), np.float32)
        h_full, _ = fleet.act(full)
        assert np.all(h_full >= 1) and np.all(h_full <= fleet.cfg.h_max)

    def test_allocation_uses_battery(self):
        fleet = make_fleet_ddpg(3, 2000, action_space="per_device")
        probe = np.ones(9, np.float32)
        probe[BATTERY_COL] = 0.0
        h, _ = fleet.allocation(probe)
        assert np.all(h == 1)
