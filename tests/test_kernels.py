"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (histogram, lgc_compress_hist, maxabs, sparsify_ef,
                           thresholds_from_counts)
from repro.kernels import ref
from repro.kernels.swa_attention import swa_decode

SHAPES = [63, 128, 1000, 8192, 40_000]
DTYPES = [jnp.float32, jnp.bfloat16]


def _vec(n, dtype, seed=0, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(seed), (n,)) * scale
            ).astype(dtype)


class TestMaxAbs:
    @pytest.mark.parametrize("n", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_matches_jnp(self, n, dtype):
        x = _vec(n, dtype, seed=n)
        got = float(maxabs(x)[0, 0])
        want = float(jnp.max(jnp.abs(x.astype(jnp.float32))))
        assert got == pytest.approx(want, rel=1e-6)

    def test_all_zero(self):
        assert float(maxabs(jnp.zeros(256))[0, 0]) == 0.0

    @pytest.mark.parametrize("block_rows", [8, 64, 256])
    def test_block_sizes(self, block_rows):
        x = _vec(10_000, jnp.float32, seed=1)
        got = float(maxabs(x, block_rows=block_rows)[0, 0])
        assert got == pytest.approx(float(jnp.max(jnp.abs(x))), rel=1e-6)


class TestHistogram:
    @pytest.mark.parametrize("n", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_matches_ref(self, n, dtype):
        x = _vec(n, dtype, seed=n + 1)
        m = maxabs(x)
        got = histogram(x, m)
        want = ref.hist_counts(x.astype(jnp.float32), m.reshape(()))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_total_count_is_d(self):
        x = _vec(5000, jnp.float32, seed=2)
        c = histogram(x, maxabs(x))
        assert int(c.sum()) == 5000  # padding corrected

    def test_thresholds_monotone(self):
        x = _vec(4096, jnp.float32, seed=3)
        m = maxabs(x)
        thr = thresholds_from_counts(histogram(x, m), m,
                                     jnp.array([64, 256, 1024]))
        t = np.asarray(thr)
        assert t[0] >= t[1] >= t[2] >= 0


class TestSparsifyEF:
    @pytest.mark.parametrize("n", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_matches_ref(self, n, dtype):
        e = _vec(n, dtype, seed=n + 10, scale=0.1)
        d = _vec(n, dtype, seed=n + 11)
        u = e.astype(jnp.float32) + d.astype(jnp.float32)
        m = maxabs(u)
        cum_ks = jnp.array([max(1, n // 50), max(2, n // 10)], jnp.int32)
        thr = thresholds_from_counts(histogram(u, m), m, cum_ks)
        recv = jnp.array([1, 1], jnp.int32)
        g, en = sparsify_ef(e, d, thr, recv)
        g_r, en_r = ref.hist_layered_sparsify(u, thr, recv)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_r),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(en), np.asarray(en_r),
                                   rtol=1e-6, atol=1e-6)

    def test_channel_drop(self):
        n = 4096
        e, d = jnp.zeros(n), _vec(n, jnp.float32, seed=4)
        m = maxabs(d)
        cum_ks = jnp.array([100, 400], jnp.int32)
        thr = thresholds_from_counts(histogram(d, m), m, cum_ks)
        g_all, _ = sparsify_ef(e, d, thr, jnp.array([1, 1]))
        g_base, e_base = sparsify_ef(e, d, thr, jnp.array([1, 0]))
        assert int((g_base != 0).sum()) < int((g_all != 0).sum())
        # dropped mass conserved in memory: g + e' == u always
        np.testing.assert_allclose(np.asarray(g_base + e_base),
                                   np.asarray(d), rtol=1e-6)


class TestFusedPipeline:
    @pytest.mark.parametrize("n", [1000, 8192, 65_536])
    @pytest.mark.parametrize("c", [1, 2, 3, 4])
    def test_matches_ref_pipeline(self, n, c):
        e = _vec(n, jnp.float32, seed=n + c, scale=0.2)
        d = _vec(n, jnp.float32, seed=n + c + 1)
        ks = np.linspace(n // 100 + 1, n // 10 + 2, c).astype(np.int32)
        cum_ks = jnp.array(np.cumsum(ks), jnp.int32)
        recv = jnp.ones((c,), jnp.int32)
        g, en = lgc_compress_hist(e, d, cum_ks, recv)
        g_r, en_r = ref.hist_lgc_compress(e, d, cum_ks, recv)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_r), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(en), np.asarray(en_r), rtol=1e-6)

    def test_selection_near_k(self):
        """Histogram selection overshoot is bounded by one bin's mass."""
        n = 50_000
        d = _vec(n, jnp.float32, seed=9)
        cum_ks = jnp.array([2500], jnp.int32)
        g, _ = lgc_compress_hist(jnp.zeros(n), d, cum_ks, jnp.array([1]))
        nsel = int((g != 0).sum())
        assert nsel >= 2500
        assert nsel <= 2500 + n // 64  # loose bin-mass bound

    def test_covers_exact_topk(self):
        """Histogram selection is a superset of exact Top_K selection."""
        n = 20_000
        d = _vec(n, jnp.float32, seed=10)
        cum_ks = jnp.array([1000], jnp.int32)
        g, _ = lgc_compress_hist(jnp.zeros(n), d, cum_ks, jnp.array([1]))
        g_exact, _ = ref.exact_lgc_compress(jnp.zeros(n), d, cum_ks,
                                            jnp.array([1]))
        exact_support = np.asarray(g_exact != 0)
        got_support = np.asarray(g != 0)
        assert np.all(got_support[exact_support])


class TestPerLayerHistParity:
    """Per-layer candidate selection (repro.core.compressor) routes big
    segments through the Pallas kernels and small ones through ref.py; the
    routing threshold must be invisible -- kernels and oracles are
    bit-equal -- including at the 10^6-element scale the routing exists
    for."""

    def test_parity_at_1e6(self):
        from repro.core.compressor import (layer_budgets,
                                           per_layer_candidates_hist)
        n_big, n_small = 1_000_000, 30_000
        u = jnp.concatenate([_vec(n_big, jnp.float32, seed=20),
                             _vec(n_small, jnp.float32, seed=21)])
        slices = [("big", 0, n_big), ("small", n_big, n_big + n_small)]
        b = layer_budgets("size_prop", u, slices, jnp.int32(4096),
                          u.shape[0])
        via_pallas = per_layer_candidates_hist(u, slices, b)   # big->kernel
        via_ref = per_layer_candidates_hist(u, slices, b,
                                            pallas_min_elems=10 ** 9)
        np.testing.assert_array_equal(np.asarray(via_pallas),
                                      np.asarray(via_ref))
        # hist selection keeps >= budget per layer, overshoot one bin
        for i, (_, lo, hi) in enumerate(slices):
            nsel = int(np.asarray(via_pallas[lo:hi]).sum())
            assert nsel >= int(b[i])
            assert nsel <= int(b[i]) + (hi - lo) // 64

    def test_kernel_vs_ref_at_1e6(self):
        x = _vec(1_000_000, jnp.float32, seed=22)
        m = maxabs(x)
        np.testing.assert_array_equal(
            np.asarray(histogram(x, m)),
            np.asarray(ref.hist_counts(x, m.reshape(()))))
        assert float(m[0, 0]) == float(ref.hist_maxabs(x))


class TestSWADecode:
    @pytest.mark.parametrize("shape", [(2, 4, 512, 64), (1, 8, 1024, 128),
                                       (4, 2, 256, 32)])
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_matches_ref(self, shape, dtype):
        b, h, w, dh = shape
        ks = jax.random.split(jax.random.PRNGKey(b * h), 4)
        q = jax.random.normal(ks[0], (b, h, dh), dtype)
        k = jax.random.normal(ks[1], (b, h, w, dh), dtype)
        v = jax.random.normal(ks[2], (b, h, w, dh), dtype)
        ln = jax.random.randint(ks[3], (b,), 1, w + 1)
        got = np.asarray(swa_decode(q, k, v, ln, chunk=128), np.float32)
        want = np.asarray(ref.swa_decode_ref(q, k, v, ln), np.float32)
        tol = 2e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(got, want, rtol=tol, atol=tol)

    def test_short_length_ignores_tail(self):
        """Garbage beyond `length` must not influence the output."""
        b, h, w, dh = 1, 2, 256, 64
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        q = jax.random.normal(ks[0], (b, h, dh))
        k = jax.random.normal(ks[1], (b, h, w, dh))
        v = jax.random.normal(ks[2], (b, h, w, dh))
        ln = jnp.array([100])
        out1 = swa_decode(q, k, v, ln, chunk=64)
        k2 = k.at[:, :, 100:].set(1e9)
        v2 = v.at[:, :, 100:].set(-1e9)
        out2 = swa_decode(q, k2, v2, ln, chunk=64)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   rtol=1e-6)
