"""Trip-count-aware cost model over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scanned-layer model (all of ours -- layers, grad accumulation, attention
chunks are lax.scan/map) is undercounted by the trip count.  This walker
parses the post-optimization HLO text and:

  * multiplies while-body costs by the loop trip count (recovered from the
    canonical counted-loop condition ``compare(iv, constant(N)), LT``);
  * counts dot FLOPs = 2 * prod(result) * prod(contracting dims) from the
    instruction's shapes + ``lhs_contracting_dims`` (matmul-FLOPs convention,
    same as MFU accounting; elementwise flops are ignored);
  * approximates HBM bytes as operand+result buffer bytes of top-level
    (post-fusion) instructions -- fusion internals are not double counted;
  * sums collective bytes (result-buffer convention) per collective kind,
    including collectives inside loop bodies.

Validated against analytic 6ND in tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}
_SHAPE_RE = re.compile(r"(pred|bf16|f8e4m3fn|f8e5m2|[sufc]\d+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s+->")
_INSTR = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+(.*)$")
_RHS = re.compile(r"^(\(.*?\)|\S+)\s+([\w\-]+)\(")
_OPERAND = re.compile(r"%([\w.\-]+)")

# ops that move no bytes / are bookkeeping
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "add-dependency", "partition-id", "replica-id",
             "iota", "rng-bit-generator", "rng", "custom-call"}


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shape_bytes(shape_str: str) -> int:
    return sum(_shape_elems(dims) * _DTYPE_BYTES[dt]
               for dt, dims in _SHAPE_RE.findall(shape_str))


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    operands: list[str]
    attrs: str
    raw: str = ""
    is_root: bool = False


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    # bytes by (group_size, cross_pod) -- cross_pod means the group spans
    # devices >= 256 apart on the 512-device multi-pod mesh (the DCN link
    # LGC compresses); used to attribute collective traffic per mesh axis.
    coll_groups: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        for k, v in other.coll.items():
            self.coll[k] += v
        for k, v in other.coll_groups.items():
            self.coll_groups[k] += v
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k,
                    defaultdict(float, {a: b * k for a, b in self.coll.items()}),
                    defaultdict(float, {a: b * k
                                        for a, b in self.coll_groups.items()}))

    @property
    def cross_pod_bytes(self) -> float:
        return sum(v for (sz, xp), v in self.coll_groups.items() if xp)


def _parse_replica_groups(attrs: str) -> tuple[int, bool]:
    """(group_size, crosses_pod_boundary) from a collective's attributes.

    Handles both the explicit ``{{0,1},{2,3}}`` form and the iota form
    ``[G,S]<=[dims]T(perm)``.  Pod boundary = members >= 256 apart (the
    multi-pod mesh is (2,16,16) over 512 devices, pod stride 256).
    """
    import numpy as np
    m = re.search(r"source_target_pairs=\{\{(\d+),(\d+)\}", attrs)
    if m:
        pairs = re.findall(r"\{(\d+),(\d+)\}", attrs)
        xp = any(abs(int(a) - int(b)) >= 256 for a, b in pairs)
        return 2, xp
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        g0 = [int(x) for x in m.group(1).split(",")]
        return len(g0), (max(g0) - min(g0)) >= 256
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\]"
                  r"(?:T\(([\d,]+)\))?", attrs)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = ([int(x) for x in m.group(4).split(",")]
                if m.group(4) else list(range(len(dims))))
        devs = np.arange(int(np.prod(dims))).reshape(dims).transpose(perm
                                                                     ).reshape(g, s)
        g0 = devs[0]
        return s, bool(g0.max() - g0.min() >= 256)
    return 0, False


class HloCostModel:
    def __init__(self, hlo_text: str, collect_breakdown: bool = False):
        self.comps: dict[str, list[Instr]] = {}
        self.shapes: dict[str, dict[str, str]] = {}
        self.entry: str | None = None
        self._memo: dict[str, Cost] = {}
        self.breakdown: dict[str, Cost] | None = \
            defaultdict(Cost) if collect_breakdown else None
        self._parse(hlo_text)

    @staticmethod
    def _tag(ins: Instr) -> str:
        m = re.search(r'op_name="([^"]+)"', ins.raw)
        return (m.group(1) if m else ins.op)[-80:]

    def _note(self, ins: Instr, cost: Cost, scale: float = 1.0):
        if self.breakdown is not None:
            self.breakdown[self._tag(ins)] += cost.scaled(scale)

    # -- parsing -----------------------------------------------------------
    def _parse(self, text: str):
        cur = None
        for line in text.splitlines():
            h = _COMP_HEADER.match(line)
            if h and line.rstrip().endswith("{"):
                cur = h.group(2)
                self.comps[cur] = []
                self.shapes[cur] = {}
                if h.group(1):
                    self.entry = cur
                # parameter shapes from the header signature
                for pname, pshape in re.findall(
                        r"([\w.\-]+):\s+((?:\([^)]*\)|[\w\[\],{}]+))",
                        h.group(3)):
                    self.shapes[cur][pname] = pshape
                continue
            if cur is None:
                continue
            if line.startswith("}"):
                cur = None
                continue
            mi = _INSTR.match(line)
            if not mi:
                continue
            is_root = line.lstrip().startswith("ROOT ")
            name, rhs = mi.group(1), mi.group(2)
            mr = _RHS.match(rhs)
            if not mr:
                continue
            shape, op = mr.group(1), mr.group(2)
            paren = rhs[mr.end() - 1:]
            # operand list: up to the matching close paren (operands are flat)
            depth, end = 0, len(paren)
            for i, ch in enumerate(paren):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operands = _OPERAND.findall(paren[:end + 1])
            attrs = paren[end + 1:]
            self.comps[cur].append(Instr(name, shape, op, operands, attrs,
                                         raw=rhs, is_root=is_root))
            self.shapes[cur][name] = shape

    # -- helpers -----------------------------------------------------------
    def _operand_bytes(self, comp: str, operands: list[str]) -> int:
        tab = self.shapes[comp]
        return sum(_shape_bytes(tab[o]) for o in operands if o in tab)

    def _trip_count(self, cond_comp: str) -> int:
        """Canonical counted loop: the s32 constant the iv is compared to.

        XLA canonicalizes lax.scan/map loops to ``iv = 0; while (iv < N)``;
        the bound N appears as an s32[] constant in the condition computation
        (possibly inside a wrapped-compare fusion).  Falls back to 1 if no
        bound is found (cost then matches XLA's own single-trip counting).
        """
        def consts_in(comp_name: str):
            for ins in self.comps.get(comp_name, []):
                if ins.op == "constant" and ins.shape.startswith("s32"):
                    m = re.search(r"constant\((-?\d+)\)", ins.raw)
                    if m:
                        yield int(m.group(1))
                elif ins.op in ("fusion", "call"):
                    c = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
                    if c:
                        yield from consts_in(c.group(1))
        best = max(consts_in(cond_comp), default=0)
        return best if best > 0 else 1

    def _fusion_bytes(self, comp: str, ins: Instr) -> float:
        """HBM bytes for a fusion call, slice-aware.

        A fusion operand consumed ONLY by dynamic-slice/gather inside the
        fused computation reads just the slice, not the whole buffer (the
        scanned-layer weight stack pattern); a fusion whose root is a
        dynamic-update-slice writes only the update.  Without this, a
        depth-L scan appears to move L x the full stacked buffer per step
        (L^2 total) -- off by ~30x for the 28-layer calibration model.
        """
        called = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
        if not called:
            return _shape_bytes(ins.shape) + self._operand_bytes(
                comp, ins.operands)
        cname = called.group(1)
        body = self.comps.get(cname, [])
        params: dict[int, str] = {}
        consumers: dict[str, list[Instr]] = defaultdict(list)
        for i2 in body:
            if i2.op == "parameter":
                mm = re.search(r"parameter\((\d+)\)", i2.raw)
                if mm:
                    params[int(mm.group(1))] = i2.name
            for o in i2.operands:
                consumers[o].append(i2)

        total = 0.0
        tab = self.shapes[comp]
        for i, opnd in enumerate(ins.operands):
            full = _shape_bytes(tab.get(opnd, ""))
            pname = params.get(i)
            cons = consumers.get(pname, []) if pname else []
            if cons and all(
                    c.op in ("dynamic-slice", "gather")
                    and c.operands and c.operands[0] == pname
                    for c in cons):
                total += sum(_shape_bytes(c.shape) for c in cons)
            elif cons and all(
                    c.op == "dynamic-update-slice"
                    and c.operands and c.operands[0] == pname
                    for c in cons):
                # buffer updated in place: only the update slice moves
                ctab = self.shapes.get(cname, {})
                total += sum(_shape_bytes(ctab.get(c.operands[1], ""))
                             for c in cons if len(c.operands) > 1)
            else:
                total += full
        # result side
        root = next((i2 for i2 in body if i2.is_root), None)
        dus = [i2 for i2 in body if i2.op == "dynamic-update-slice"]
        if dus and root is not None and root.op in (
                "dynamic-update-slice", "bitcast", "copy", "tuple"):
            ctab = self.shapes.get(cname, {})
            total += sum(_shape_bytes(ctab.get(d.operands[1], ""))
                         for d in dus if len(d.operands) > 1)
        else:
            total += _shape_bytes(ins.shape)
        return total

    def _generic_bytes(self, comp: str, ins: Instr) -> float:
        """Slice-aware bytes for non-fusion top-level ops."""
        op = ins.op
        tab = self.shapes[comp]
        if op == "dynamic-slice":
            return 2.0 * _shape_bytes(ins.shape)
        if op == "dynamic-update-slice" and len(ins.operands) > 1:
            return 2.0 * _shape_bytes(tab.get(ins.operands[1], ""))
        if op == "gather":
            idx = _shape_bytes(tab.get(ins.operands[1], "")) \
                if len(ins.operands) > 1 else 0
            return 2.0 * _shape_bytes(ins.shape) + idx
        if op == "scatter" and len(ins.operands) > 2:
            return (2.0 * _shape_bytes(tab.get(ins.operands[2], ""))
                    + _shape_bytes(tab.get(ins.operands[1], "")))
        return _shape_bytes(ins.shape) + self._operand_bytes(
            comp, ins.operands)

    def _dot_flops(self, comp: str, ins: Instr) -> float:
        result_elems = _shape_elems(_SHAPE_RE.search(ins.shape).group(2)) \
            if _SHAPE_RE.search(ins.shape) else 0
        lhs = ins.operands[0] if ins.operands else None
        lhs_shape = self.shapes[comp].get(lhs, "")
        lhs_dims = _shape_dims(lhs_shape)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
        k = 1
        if m and lhs_dims:
            for d in m.group(1).split(","):
                if d:
                    k *= lhs_dims[int(d)]
        return 2.0 * result_elems * k

    # -- main recursion ------------------------------------------------------
    def cost_of(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        total = Cost()
        self._memo[comp] = total          # guard cycles
        for ins in self.comps.get(comp, []):
            op = ins.op
            if op in _FREE_OPS:
                continue
            if op == "while":
                cond = re.search(r"condition=%?([\w.\-]+)", ins.attrs)
                body = re.search(r"body=%?([\w.\-]+)", ins.attrs)
                trips = self._trip_count(cond.group(1)) if cond else 1
                if body:
                    total += self.cost_of(body.group(1)).scaled(trips)
                continue
            if op == "conditional":
                branches = re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                      r"(?:true|false)_computation=%?([\w.\-]+))",
                                      ins.attrs)
                names = []
                for grp, single in branches:
                    if grp:
                        names += _OPERAND.findall(grp)
                    if single:
                        names.append(single)
                if names:
                    worst = max((self.cost_of(n) for n in names),
                                key=lambda c: c.flops + c.bytes)
                    total += worst
                continue
            if op in ("call", "async-start"):
                c = re.search(r"(?:to_apply|calls)=%?([\w.\-]+)", ins.attrs)
                if c:
                    total += self.cost_of(c.group(1))
                continue
            if op == "fusion":
                c = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
                if c:
                    total.flops += self._flops_only(c.group(1))
                total.bytes += self._fusion_bytes(comp, ins)
                continue
            if op.startswith(_COLLECTIVES) or any(
                    op == k or op == k + "-start" for k in _COLLECTIVES):
                kind = next(k for k in _COLLECTIVES if op.startswith(k))
                if op.endswith("-done"):
                    continue
                b = _shape_bytes(ins.shape)
                total.coll[kind] += b
                total.coll_groups[_parse_replica_groups(ins.attrs)] += b
                total.bytes += b + self._operand_bytes(comp, ins.operands)
                continue
            if op.endswith("-done"):
                continue
            # generic top-level op (dot, copy, reduce, sort, gather, ...)
            if op in ("dot", "convolution"):
                total.flops += self._dot_flops(comp, ins)
            total.bytes += self._generic_bytes(comp, ins)
        self._memo[comp] = total
        return total

    def _flops_only(self, comp: str, _seen=None) -> float:
        _seen = _seen or set()
        if comp in _seen:
            return 0.0
        _seen.add(comp)
        f = 0.0
        for ins in self.comps.get(comp, []):
            if ins.op in ("dot", "convolution"):
                f += self._dot_flops(comp, ins)
            else:
                c = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", ins.attrs)
                if c and ins.op in ("fusion", "call"):
                    f += self._flops_only(c.group(1), _seen)
        return f

    def total(self) -> Cost:
        assert self.entry, "no ENTRY computation found"
        return self.cost_of(self.entry)


def analyze_hlo(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).total()


def breakdown_hlo(hlo_text: str, top: int = 20) -> list[tuple[str, Cost]]:
    """Per-op_name cost rows (scaled by loop trips), sorted by flops+bytes.

    Mirrors cost_of()'s accounting exactly but tags every contribution with
    its HLO metadata op_name -- the profiling view used by §Perf iterations.
    """
    m = HloCostModel(hlo_text, collect_breakdown=True)
    rows: dict[str, Cost] = defaultdict(Cost)

    def walk(comp: str, scale: float):
        for ins in m.comps.get(comp, []):
            op = ins.op
            if op in _FREE_OPS:
                continue
            tag = m._tag(ins)
            if op == "while":
                cond = re.search(r"condition=%?([\w.\-]+)", ins.attrs)
                body = re.search(r"body=%?([\w.\-]+)", ins.attrs)
                trips = m._trip_count(cond.group(1)) if cond else 1
                if body:
                    walk(body.group(1), scale * trips)
                continue
            if op == "conditional":
                continue
            if op in ("call", "async-start"):
                c = re.search(r"(?:to_apply|calls)=%?([\w.\-]+)", ins.attrs)
                if c:
                    walk(c.group(1), scale)
                continue
            if op == "fusion":
                c = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
                f = m._flops_only(c.group(1)) if c else 0.0
                b = m._fusion_bytes(comp, ins)
                rows[tag] += Cost(f * scale, b * scale)
                continue
            if op.startswith(_COLLECTIVES):
                if op.endswith("-done"):
                    continue
                kind = next(k for k in _COLLECTIVES if op.startswith(k))
                b = _shape_bytes(ins.shape)
                rows[tag] += Cost(0, (b + m._operand_bytes(comp, ins.operands))
                                  * scale,
                                  defaultdict(float, {kind: b * scale}))
                continue
            if op.endswith("-done"):
                continue
            f = m._dot_flops(comp, ins) if op in ("dot", "convolution") else 0.0
            rows[tag] += Cost(f * scale, m._generic_bytes(comp, ins) * scale)

    walk(m.entry, 1.0)
    return sorted(rows.items(),
                  key=lambda kv: -(kv[1].flops / 197e12 + kv[1].bytes / 819e9)
                  )[:top]
