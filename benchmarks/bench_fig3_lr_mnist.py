"""Paper Figure 3: LR on MNIST -- convergence + energy + money vs baselines.

Compares LGC (fixed controller = "LGC w/o DRL"), LGC+DDPG, FedAvg and Top-k
single channel under identical round budgets; reports final loss/accuracy
and total resource spend.  Reduced rounds for the harness run; pass
--rounds for the full curve.
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.core import (FLConfig, LGCSimulator, make_fleet_ddpg,
                        run_baseline, tree_size)
from repro.models.paper_models import make_mnist_task

from .common import emit


def run(model: str = "lr", rounds: int = 150, n_train: int = 3000,
        emit_csv: bool = True) -> dict:
    task = make_mnist_task(model, m_devices=3, n_train=n_train)
    cfg = FLConfig(rounds=rounds, eval_every=max(rounds // 10, 1))
    out = {}

    for mode, label in (("lgc", "lgc_fixed"), ("fedavg", "fedavg"),
                        ("topk", "topk_1ch")):
        t0 = time.time()
        h = run_baseline(task, cfg, mode, h=4)
        out[label] = h.asdict()
        if emit_csv:
            emit(f"fig3_{model}_{label}",
                 (time.time() - t0) * 1e6 / rounds,
                 f"acc={h.accuracy[-1]:.3f};loss={h.loss[-1]:.3f};"
                 f"energy_j={h.energy_j[-1]:.0f};money={h.money[-1]:.4f};"
                 f"uplink_mb={h.uplink_mb[-1]:.2f}")

    # LGC + DDPG (the paper's full system; one jitted fleet call/boundary)
    d = tree_size(task.init(jax.random.PRNGKey(0)))
    fleet = make_fleet_ddpg(3, d)
    t0 = time.time()
    h = LGCSimulator(task, cfg, fleet, mode="lgc").run()
    out["lgc_ddpg"] = h.asdict()
    out["ddpg_rewards"] = [float(r) for rs in fleet.rewards for r in rs]
    if emit_csv:
        emit(f"fig3_{model}_lgc_ddpg", (time.time() - t0) * 1e6 / rounds,
             f"acc={h.accuracy[-1]:.3f};loss={h.loss[-1]:.3f};"
             f"energy_j={h.energy_j[-1]:.0f};money={h.money[-1]:.4f};"
             f"uplink_mb={h.uplink_mb[-1]:.2f}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=150)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    res = run(rounds=args.rounds)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
