"""Federated learning with Layered Gradient Compression (paper Algorithm 1).

Faithful per-iteration simulator:

    for t in 0..T-1:
      every device m:   w_hat^{t+1/2} = w_hat^t - eta(t) * grad f_m(w_hat^t; D_m^t)
      if t+1 in I_m:    u = e_m + w_m - w_hat^{t+1/2}
                        g_m = LGC_k(u);  upload layers over channels
                        e_m <- u - g_received
                        receive global model: w_m, w_hat_m <- w_global
      else:             w_hat <- w_hat^{t+1/2};  w_m, e_m unchanged
      server:           w_global <- w_global - (1/M) sum_{m synced} g_m

    Asynchronous sync sets I_m with gap(I_m) <= H (paper Definition 1) are
produced by the controller: after each sync it picks H_m (next gap, local
computation) and D_{m,n} (coordinates per channel).  Both engines talk to
ONE fleet-shaped controller per simulation through the batched controller
protocol below (one ``act`` / ``observe`` call per sync boundary with
(M, .) arrays); per-device controller lists are adapted by the
:class:`ControllerFleet` shim, and :class:`repro.core.controller.FleetDDPG`
implements the protocol natively with jitted (M, .) programs.

Two engines implement the same algorithm:

* ``engine="batched"`` (default) -- per-device state is stacked into
  leading-axis-M pytrees and whole sync windows (local SGD rounds + channel
  sampling + layered compression + error feedback + the server mean) compile
  to one XLA program via ``jax.vmap`` + ``jax.lax.scan``
  (:mod:`repro.core.fl_batched`).  Controller decisions stay host-side at
  sync boundaries.
* ``engine="loop"`` -- the reference Python loop over devices (this module).

Both engines draw every random variate from the same counter-based key
scheme (:func:`stream_key`), so for a fixed seed they simulate the *same*
trajectory: identical minibatches, channel realisations and eval subsets.
The engines therefore agree on History up to float reduction order
(tests/test_fl.py::TestEngineEquivalence).  Environment dynamics beyond the
memoryless seed model -- Gauss-Markov bandwidth, Gilbert-Elliott burst
availability, device dropout/stragglers -- come from
:mod:`repro.core.scenario` via ``FLConfig.scenario``; the per-device chain
carry is advanced once per simulated round by every engine from the same
TAG_SCEN stream, so the equivalence invariant extends to every scenario
(tests/test_scenarios.py).

The simulator accounts energy / money / wall-time per round using the
multi-channel model in :mod:`repro.core.channels` and supports the paper's
baselines (FedAvg; LGC with a fixed controller) plus extras (Top-k single
channel, LGC+QSGD int8).  ``backend="pallas"`` routes the flat-vector EF hot
path through the fused Pallas kernel (:func:`repro.kernels.lgc_compress_hist`,
histogram-threshold selection); ``backend="exact"`` (default) keeps the
rank-exact oracle in :mod:`repro.core.compressor` as the reference.

docs/ARCHITECTURE.md is the narrative behind all of the above (engines §1,
key streams §3, controller protocol §6); change nothing here without
reading it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .channels import (DEFAULT_CHANNELS, ChannelSpec, DeviceProfile,
                       comm_cost, comp_cost, stack_specs)
from .compressor import (LAYER_POLICIES, LGCCompressor, flatten_tree,
                         layer_budgets, per_layer_candidates_hist,
                         per_layer_compress, tree_layer_slices, tree_size,
                         unflatten_like, wire_bytes)
from .error_feedback import EFState, ef_compress
# counter-based randomness and environment dynamics live one layer below, in
# repro.core.scenario; the tags and stream_key are re-exported here because
# every engine/controller/test imports them from this module
from .scenario import (TAG_BATCH, TAG_CHANNEL, TAG_COHORT,  # noqa: F401
                       TAG_CTRL_NOISE, TAG_CTRL_SAMPLE, TAG_DROP, TAG_EVAL,
                       TAG_QUANT, TAG_REWARD, TAG_SCEN, TAG_SCEN_INIT,
                       Scenario, dropout_mask, get_scenario, init_carry,
                       sample_from_carry, step_carry, stream_key)
from .server import (diloco_update, get_aggregator, init_server_state,
                     semi_sync_sums, semi_sync_update, staleness_schedule,
                     window_deadline)

Array = jax.Array


# ---------------------------------------------------------------------------
# model + data interfaces (duck-typed; built by the task zoo factories in
# repro.models.paper_models -- TASKS / make_task)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FLTask:
    """A learning task: init/loss/eval + per-device data shards."""
    init: Callable[[Array], dict]                      # key -> params pytree
    loss_fn: Callable[[dict, tuple], Array]            # (params, batch) -> scalar
    metric_fn: Callable[[dict, tuple], Array]          # accuracy (or -loss)
    device_data: Sequence[tuple[np.ndarray, np.ndarray]]  # per-device (X, y)
    eval_data: tuple[np.ndarray, np.ndarray]
    name: str = "task"


@dataclasses.dataclass
class FLConfig:
    rounds: int = 500                  # T: global iteration budget
    batch_size: int = 64               # b (paper: 64)
    lr: float = 0.01                   # paper: 0.01
    lr_decay_a: float = 200.0          # eta(t) = lr * a / (a + t) (decaying)
    max_gap: int = 8                   # H: uniform bound on gap(I_m)
    channels: Sequence[ChannelSpec] = DEFAULT_CHANNELS
    device_profiles: Sequence[DeviceProfile] | None = None
    seed: int = 0
    eval_every: int = 10
    value_bytes: int = 4               # fp32 values on the wire
    index_bytes: int = 4
    engine: str = "batched"            # "batched" | "loop" | "sharded"
    backend: str = "exact"             # "exact" | "pallas"
    # environment dynamics: a repro.core.scenario.Scenario or a registry name
    # ("static", "markov_urban", "gilbert_flaky", ...); "static" reproduces
    # the memoryless seed model exactly
    scenario: str | Scenario = "static"
    # per-model-layer budget policy: "global" (flat top-k over the whole
    # vector -- the paper's LGC, bit-identical to pre-policy code) or a
    # repro.core.compressor.LAYER_POLICIES name ("uniform", "size_prop",
    # "divergence"); "uniform" is bit-equal to "global" on the exact backend
    layer_policy: str = "global"
    # server aggregation (repro.core.server.AGGREGATORS): "mean" is today's
    # synchronous path and keeps the engines bitwise on their original code;
    # "diloco" adds a Nesterov outer step; "semi_sync" adds the bounded-
    # staleness deadline server.  Contract in docs/ARCHITECTURE.md §11.
    aggregator: str = "mean"
    staleness_cap: int = 0             # semi_sync: max windows an update waits
    staleness_alpha: float = 0.5       # semi_sync: w(s) = 1/(1+s)^alpha
    deadline_factor: float = 1.25      # semi_sync: deadline = factor * median
    outer_lr: float = 0.7              # diloco outer step size
    outer_momentum: float = 0.9        # diloco outer Nesterov momentum
    # controller action space (docs/ARCHITECTURE.md §13):
    # * "shared"     -- the pre-existing semantics: each device's h_m sets its
    #   own next sync round (windows break at every device's boundary).
    #   Bitwise-identical to the code before the action space existed.
    # * "per_device" -- uniform sync windows of max_gap rounds (every device
    #   syncs at every boundary); h_m in [1, max_gap] is the number of local
    #   SGD steps the device actually computes (the first h_m rounds of the
    #   window; the rest it idles, saving compute energy).  The controller
    #   observation grows to spend + device profile (battery, compute
    #   multiplier) + per-channel state (repro.core.controller.obs_dim), and
    #   decode_actions clamps h_m by the device's battery.
    action_space: str = "shared"
    # pipeline controller decisions with the compute: at each sync boundary
    # the engine COMMITS the decision staged at the previous boundary and
    # stages a fresh one -- so the batched engine can dispatch the next
    # window before doing reward evaluation / fleet training, taking the
    # controller off the critical path.  Decisions then act on one-window-old
    # observations (window t+1 is decided from window t-1's state).
    pipeline_decisions: bool = False


@dataclasses.dataclass
class RoundDecision:
    """Controller output for one device for its next sync window."""
    h: int                              # local steps until next sync
    ks: Sequence[int]                   # coordinates per channel (layer sizes)


class FixedController:
    """LGC without DRL: fixed local computation + fixed traffic allocation."""

    def __init__(self, h: int, ks: Sequence[int]):
        self.h, self.ks = h, list(ks)

    def act(self, state: np.ndarray) -> RoundDecision:
        return RoundDecision(self.h, self.ks)

    def observe(self, *a, **k):  # no learning
        pass


# ---------------------------------------------------------------------------
# batched controller protocol
# ---------------------------------------------------------------------------
#
# Both engines talk to ONE fleet-shaped controller per simulation instead of
# M per-device objects.  The protocol (duck-typed):
#
#   needs_reward : (M,) bool -- which devices want a reward signal (gates the
#                  per-device TAG_REWARD eval so fixed fleets skip it)
#   act(states: (M, S), mask: (M,) bool) -> (h: (M,), ks: (M, C) rows)
#                  decide H_m and the per-channel budgets for every masked
#                  device; unmasked rows are ignored and must not advance
#                  any per-device random stream
#   observe(loss_drops: (M,), new_states: (M, S), mask: (M,) bool)
#                  deliver the post-round reward signal to masked devices
#
# :class:`ControllerFleet` adapts a list of per-device controllers
# (.act(state) -> RoundDecision, optional .reward(loss_drop, new_state)) to
# this protocol; :class:`repro.core.controller.FleetDDPG` implements it
# natively with one jitted (M, .) call per boundary.

class ControllerFleet:
    """List->fleet shim over per-device controllers (the reference path)."""

    def __init__(self, controllers: Sequence):
        self.controllers = list(controllers)
        self.needs_reward = np.array(
            [hasattr(c, "reward") for c in self.controllers], bool)

    @property
    def m(self) -> int:
        return len(self.controllers)

    def act(self, states: np.ndarray, mask: np.ndarray | None = None):
        mask = np.ones(self.m, bool) if mask is None else np.asarray(mask)
        h = np.zeros(self.m, np.int64)
        ks: list[Sequence[int]] = [()] * self.m
        for i in np.nonzero(mask)[0]:
            dec = self.controllers[i].act(np.asarray(states[i]))
            h[i], ks[i] = dec.h, list(dec.ks)
        return h, ks

    def observe(self, loss_drops: np.ndarray, new_states: np.ndarray,
                mask: np.ndarray | None = None):
        mask = np.ones(self.m, bool) if mask is None else np.asarray(mask)
        for i in np.nonzero(mask)[0]:
            c = self.controllers[i]
            if hasattr(c, "reward"):
                c.reward(float(loss_drops[i]), np.asarray(new_states[i]))
            else:
                c.observe(float(loss_drops[i]), np.asarray(new_states[i]))


@dataclasses.dataclass
class History:
    """Recorded metrics, one entry per eval point / per sync."""
    step: list[int] = dataclasses.field(default_factory=list)
    loss: list[float] = dataclasses.field(default_factory=list)
    accuracy: list[float] = dataclasses.field(default_factory=list)
    energy_j: list[float] = dataclasses.field(default_factory=list)
    money: list[float] = dataclasses.field(default_factory=list)
    time_s: list[float] = dataclasses.field(default_factory=list)
    uplink_mb: list[float] = dataclasses.field(default_factory=list)
    rewards: list[float] = dataclasses.field(default_factory=list)
    drl_loss: list[float] = dataclasses.field(default_factory=list)
    # simulated server wall-clock: sync aggregators advance it by the
    # slowest syncing device's window time; semi_sync by min(deadline, that)
    server_wall_s: list[float] = dataclasses.field(default_factory=list)

    def asdict(self):
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# the simulator
# ---------------------------------------------------------------------------

class LGCSimulator:
    """Runs Algorithm 1 for M devices with per-device controllers."""

    def __init__(self, task: FLTask, cfg: FLConfig,
                 controllers, mode: str = "lgc",
                 engine: str | None = None, backend: str | None = None,
                 mesh=None, server_reduce: str = "gather"):
        """mode: 'lgc' (layered, multi-channel), 'topk' (single channel),
        'fedavg' (dense upload, fastest channel, no compression),
        'lgc_q8' (LGC + QSGD int8 values).

        ``controllers`` is either a fleet-shaped controller implementing the
        batched protocol above, or a sequence of per-device controllers
        (wrapped in a :class:`ControllerFleet` shim).

        ``engine="sharded"`` partitions the batched engine's device axis over
        the FL axis of ``mesh`` (default: a host mesh over all present jax
        devices); ``server_reduce`` picks the collective that crosses the
        slow axis ("gather" -- bit-identical History -- or "psum")."""
        self.task, self.cfg, self.mode = task, cfg, mode
        self.engine = engine or cfg.engine
        self.backend = backend or cfg.backend
        self.mesh, self.server_reduce = mesh, server_reduce
        assert self.engine in ("batched", "loop", "sharded"), self.engine
        assert self.backend in ("exact", "pallas"), self.backend
        if cfg.layer_policy != "global" and cfg.layer_policy not in LAYER_POLICIES:
            raise ValueError(
                f"unknown layer_policy {cfg.layer_policy!r}; expected "
                f"'global' or one of {sorted(LAYER_POLICIES)}")
        self.m_devices = len(task.device_data)
        if cfg.action_space not in ("shared", "per_device"):
            raise ValueError(
                f"unknown action_space {cfg.action_space!r}; expected "
                f"'shared' or 'per_device'")
        self.per_device = cfg.action_space == "per_device"
        if isinstance(controllers, (list, tuple)):
            self.fleet = ControllerFleet(controllers)
            self.controllers = list(controllers)
        else:
            self.fleet = controllers
            self.controllers = list(getattr(controllers, "controllers", ()))
        assert self.fleet.m == self.m_devices, (self.fleet.m, self.m_devices)
        key = jax.random.PRNGKey(cfg.seed)
        self.params = task.init(key)                 # global model  w_global
        self.d = tree_size(self.params)
        # server aggregation mode (docs/ARCHITECTURE.md §11): "mean" keeps
        # every engine on its original inline server code (bitwise rung);
        # diloco/semi_sync thread a ServerState carry through the windows
        self.agg = get_aggregator(cfg.aggregator)
        self.server_state = (init_server_state(cfg, self.d)
                             if self.agg.carries_state else None)
        self.server_wall_s = 0.0                     # simulated f64, host-side
        self._server_apply = (jax.jit(self._make_server_apply())
                              if self.agg.name != "mean" else None)
        self.scenario = get_scenario(cfg.scenario)
        profiles = (list(cfg.device_profiles) if cfg.device_profiles
                    else self.scenario.device_profiles(self.m_devices))
        self.profiles = profiles

        # per-device state (Algorithm 1 line 1)
        self.w_hat = [self.params for _ in range(self.m_devices)]
        self.w_anchor = [flatten_tree(self.params) for _ in range(self.m_devices)]
        self.ef = [EFState(jnp.zeros((self.d,), jnp.float32))
                   for _ in range(self.m_devices)]
        self.next_sync = [0] * self.m_devices        # t at which device syncs
        self.win_start = [0] * self.m_devices        # t the decision committed
        self.decisions = [None] * self.m_devices
        self.staged = [None] * self.m_devices        # pipeline_decisions only
        self.decision_log: list[tuple] = []          # (t, m, h, ks) committed
        self.spend = [dict(energy_j=0.0, money=0.0, time_s=0.0, mb=0.0)
                      for _ in range(self.m_devices)]
        self.prev_loss = [None] * self.m_devices

        self._sgd_step = jax.jit(self._make_sgd_step())
        self._eval = jax.jit(self._make_eval())
        self._base = jax.random.PRNGKey(cfg.seed + 1)   # event-key base
        self._reward_eval = jax.jit(self._make_reward_eval())
        self._eval_xy = None            # eval data as jnp arrays, lazily

        # scenario state: per-device channel-chain carries, stacked (M, C).
        # Stationary-initialized from the TAG_SCEN_INIT stream; advanced one
        # step per simulated round by whichever engine runs (the batched
        # engine threads this carry through its window scan, the loop engine
        # advances it with one vmapped jitted call per round).
        self._dev_ids = jnp.arange(self.m_devices, dtype=jnp.int32)
        self._consts = stack_specs(cfg.channels)
        scn, base, n_ch = self.scenario, self._base, len(cfg.channels)
        self.scen_carry = jax.vmap(
            lambda i: init_carry(scn, base, i, n_ch))(self._dev_ids)
        self._scen_step_all = jax.jit(
            lambda carry, t: jax.vmap(
                lambda c, i: step_carry(scn, base, c, t, i,
                                        jnp.bool_(True)))(carry,
                                                          self._dev_ids))

        # per_device observations: static profile features (battery, compute
        # multiplier relative to the generic profile) + a host snapshot of
        # the per-channel chain state, refreshed by the engines at sync
        # boundaries from the (M, C) scenario carry they just advanced
        base_prof = DeviceProfile()
        self._profile_feats = np.array(
            [[p.battery, p.comp_time_per_step_s / base_prof.comp_time_per_step_s]
             for p in profiles], np.float32)
        self._chan_state = np.ones((self.m_devices, n_ch), np.float32)
        self._update_chan_state(self.scen_carry)

    # -- jitted pieces ------------------------------------------------------
    def _make_sgd_step(self):
        loss_fn = self.task.loss_fn

        def step(params, batch, eta):
            g = jax.grad(loss_fn)(params, batch)
            return jax.tree_util.tree_map(lambda p, gi: p - eta * gi, params, g)
        return step

    def _make_eval(self):
        def ev(params, batch):
            return self.task.loss_fn(params, batch), self.task.metric_fn(params, batch)
        return ev

    def _make_reward_eval(self):
        """(M,)-batched TAG_REWARD eval: ONE jitted program per boundary
        instead of an O(M) host loop of key/gather/eval round-trips.

        The per-device body (keyed 512-subset gather + loss) runs under
        ``jax.lax.map``, whose compilation is batch-shape independent on
        XLA:CPU, so each row is bit-identical to the old per-device
        ``_eval_subset(TAG_REWARD, (t, m), 512)`` path
        (tests/test_fl.py::TestBatchedRewardEval)."""
        loss_fn = self.task.loss_fn
        n = int(self.task.eval_data[0].shape[0])
        n_take = min(512, n)
        base = self._base

        def one(params, xe, ye, t, m):
            key = stream_key(base, TAG_REWARD, t, m)
            idx = jax.random.randint(key, (n_take,), 0, n)
            return loss_fn(params, (xe[idx], ye[idx]))

        def batched(params, xe, ye, t, ms):
            return jax.lax.map(lambda mm: one(params, xe, ye, t, mm), ms)
        return batched

    def _reward_losses(self, ms: Sequence[int], t: int,
                       params=None) -> list[float]:
        """Per-device keyed-subset eval losses for devices ``ms`` at round
        ``t``, in one jitted call (rows padded to a power of two so the
        fleet's varying sync-set sizes compile only a few programs).

        ``params`` overrides the live global model: the pipelined batched
        engine defers this eval until after it has dispatched (and rebound
        params for) the next window, passing the boundary-time handle --
        valid because params is never donated."""
        if self._eval_xy is None:
            xb, yb = self.task.eval_data
            self._eval_xy = (jnp.asarray(xb), jnp.asarray(yb))
        ms = list(ms)
        pad = (1 << max(0, (len(ms) - 1)).bit_length()) - len(ms)
        rows = jnp.asarray(ms + [ms[-1]] * pad, jnp.int32)
        losses = self._reward_eval(
            self.params if params is None else params, *self._eval_xy,
            jnp.int32(t), rows)
        return [float(l) for l in np.asarray(losses)[: len(ms)]]

    def _make_server_apply(self):
        """Jitted non-mean server round for the loop engine: padded (N, d)
        stacked updates in, (new_flat, ServerState, undelivered) out.  The
        same :mod:`repro.core.server` math the batched window traces, so
        the diloco/semi_sync loop~batched rung holds at float tolerance."""
        cfg, m_total = self.cfg, self.m_devices
        if self.agg.name == "diloco":
            lr, mu = float(cfg.outer_lr), float(cfg.outer_momentum)

            def apply(flat, state, g, mask, T, deadline):
                fold = jnp.any(mask)
                delta = jnp.sum(jnp.where(mask[:, None], g, 0.0),
                                axis=0) / m_total
                new_flat, state = diloco_update(flat, state, delta, fold,
                                                lr, mu)
                return new_flat, state, jnp.zeros_like(T)
        else:  # semi_sync
            alpha, cap = float(cfg.staleness_alpha), int(cfg.staleness_cap)

            def apply(flat, state, g, mask, T, deadline):
                fold = jnp.any(mask)
                _, _, _, undeliv = staleness_schedule(T, deadline, mask,
                                                      alpha, cap)
                g_now, contrib, _ = semi_sync_sums(g, T, mask, deadline,
                                                   alpha, cap)
                new_flat, state = semi_sync_update(flat, state, g_now,
                                                   contrib, fold, m_total)
                return new_flat, state, undeliv
        return apply

    def _window_deadline(self, ms: Sequence[int]) -> float:
        """Semi-sync uplink deadline for the sync set ``ms`` (host f64;
        committed decisions + nominal channels + straggler profiles, so
        every engine derives the identical number for the same window)."""
        items = [(self.decisions[m].h, self.decisions[m].ks,
                  self.profiles[m]) for m in ms]
        if not items:
            return 1.0
        return window_deadline(self.cfg, self.mode, self.d, items)

    def _apply_server_nonmean(self, updates, sync_ms, t32s, deadline: float):
        """One diloco/semi_sync server round (loop engine): pad the sync
        set to a power of two (compile-count bound, like _reward_losses),
        apply the jitted server math, and hand the undelivered semi-sync
        mass back to each device's EF -- mirroring the batched window's
        in-program ``ef += undeliv * g``."""
        n = len(updates)
        size = 1 << max(0, (n - 1)).bit_length()
        pad = size - n
        g = jnp.stack(updates)
        if pad:
            g = jnp.concatenate(
                [g, jnp.zeros((pad, self.d), jnp.float32)], axis=0)
        mask = jnp.asarray([True] * n + [False] * pad)
        T = jnp.asarray(list(t32s) + [np.float32(0.0)] * pad, jnp.float32)
        flat = flatten_tree(self.params)
        new_flat, self.server_state, undeliv = self._server_apply(
            flat, self.server_state, g, mask, T, jnp.float32(deadline))
        self.params = unflatten_like(new_flat, self.params)
        if self.agg.name == "semi_sync":
            un = np.asarray(undeliv)[:n]
            for j, m in enumerate(sync_ms):
                if un[j] > 0.0:
                    self.ef[m] = EFState(self.ef[m].e
                                         + float(un[j]) * updates[j])

    # -- helpers ------------------------------------------------------------
    def _eta(self, t: int) -> float:
        a = self.cfg.lr_decay_a
        return self.cfg.lr * a / (a + t)

    def _sample_batch(self, m: int, t: int):
        x, y = self.task.device_data[m]
        key = stream_key(self._base, TAG_BATCH, t, m)
        idx = np.asarray(jax.random.randint(key, (self.cfg.batch_size,),
                                            0, x.shape[0]))
        return jnp.asarray(x[idx]), jnp.asarray(y[idx])

    def _eval_subset(self, tag: int, ids: tuple, n_take: int
                     ) -> tuple[float, float]:
        """(loss, accuracy) of the global model on a keyed eval subset."""
        xb, yb = self.task.eval_data
        n = xb.shape[0]
        key = stream_key(self._base, tag, *ids)
        idx = np.asarray(jax.random.randint(key, (min(n_take, n),), 0, n))
        loss, acc = self._eval(self.params, (jnp.asarray(xb[idx]),
                                             jnp.asarray(yb[idx])))
        return float(loss), float(acc)

    def _controller_states(self) -> np.ndarray:
        """Controller state of every device: (M, 4) resource spends, plus --
        under ``action_space="per_device"`` -- the device profile (battery,
        compute multiplier) and the per-channel chain-state snapshot, (M,
        4 + 2 + C) total (repro.core.controller.obs_dim)."""
        spend = np.array([[s["energy_j"], s["money"], s["time_s"], s["mb"]]
                          for s in self.spend], np.float32)
        if not self.per_device:
            return spend
        return np.concatenate([spend, self._profile_feats, self._chan_state],
                              axis=1)

    def _update_chan_state(self, carry):
        """Snapshot the scenario carry to the host observation features:
        effective relative bandwidth exp(bw_log) * good per channel.  A
        *snapshot* (not a lazy read) because the batched engines donate the
        carry buffers to the next window program."""
        if not self.per_device:
            return
        bw = np.asarray(carry.bw_log, np.float32)
        good = np.asarray(carry.good)
        self._chan_state = (np.exp(bw) * good).astype(np.float32)

    def _fleet_decide(self, ms: Sequence[int], t: int) -> dict:
        """One fleet act for the devices in ``ms`` -> {m: RoundDecision}."""
        mask = np.zeros(self.m_devices, bool)
        mask[ms] = True
        h_arr, ks_arr = self.fleet.act(self._controller_states(), mask)
        n_ch = len(self.cfg.channels)
        out = {}
        for m in ms:
            h = int(np.clip(int(h_arr[m]), 1, self.cfg.max_gap))
            # one layer per channel: pad/trim the controller's budgets so both
            # engines see the same (and the cost model's shapes line up)
            ks = ([int(k) for k in ks_arr[m]] + [0] * n_ch)[:n_ch]
            out[m] = RoundDecision(h, ks)
        return out

    def _commit_decision(self, m: int, t: int, dec: RoundDecision):
        """Make ``dec`` the live decision for device ``m``'s next window.

        ``shared``: h_m is the window length (the device's own next sync).
        ``per_device``: every device syncs each max_gap rounds; h_m is how
        many of those rounds it actually computes (the engines mask the
        rest), so heterogeneous h never fragments the windows."""
        self.decisions[m] = dec
        self.win_start[m] = t
        self.next_sync[m] = t + (self.cfg.max_gap if self.per_device
                                 else dec.h)
        self.decision_log.append((t, m, dec.h, tuple(dec.ks)))

    def _commit_staged(self, ms: Sequence[int], t: int):
        """Pipelined commit: adopt the decisions staged at each device's
        previous boundary.  At t=0 nothing is staged yet -- the first act
        serves both the first window and the first staged decision (window 1
        is decided from the initial state, i.e. window -1's observations)."""
        ms = list(ms)
        missing = [m for m in ms if self.staged[m] is None]
        if missing:
            fresh = self._fleet_decide(missing, t)
            for m in missing:
                self.staged[m] = fresh[m]
        for m in ms:
            self._commit_decision(m, t, self.staged[m])

    def _stage_decisions(self, ms: Sequence[int], t: int):
        """Pipelined stage: act now, commit at the next boundary.  The
        batched engine calls this AFTER dispatching the next window, so the
        fleet's jitted act/train programs overlap device compute."""
        ms = list(ms)
        if not ms:
            return
        fresh = self._fleet_decide(ms, t)
        for m in ms:
            self.staged[m] = fresh[m]

    def _decide_devices(self, ms: Sequence[int], t: int):
        """One controller boundary for the devices in ``ms``: commit their
        decisions for the window starting at ``t`` (and, when pipelined,
        stage the decisions for the window after it)."""
        ms = list(ms)
        if not ms:
            return
        if self.cfg.pipeline_decisions:
            self._commit_staged(ms, t)
            self._stage_decisions(ms, t)
            return
        fresh = self._fleet_decide(ms, t)
        for m in ms:
            self._commit_decision(m, t, fresh[m])

    # -- main loop ----------------------------------------------------------
    def run(self) -> History:
        if self.engine == "batched":
            from .fl_batched import BatchedEngine
            return BatchedEngine(self).run()
        if self.engine == "sharded":
            from .fl_batched import ShardedEngine
            return ShardedEngine(self, mesh=self.mesh,
                                 server_reduce=self.server_reduce).run()
        return self._run_loop()

    def _run_loop(self) -> History:
        hist = History()
        cfg = self.cfg
        self._decide_devices(range(self.m_devices), 0)
        for t in range(cfg.rounds):
            if not self.scenario.is_static:
                # channels evolve every round, synced or not (same order as
                # the batched engine's window scan)
                self.scen_carry = self._scen_step_all(self.scen_carry,
                                                      jnp.int32(t))
            eta = self._eta(t)
            updates, sync_ms, walls, t32s = [], [], [], []
            for m in range(self.m_devices):
                # per_device: the device computes only the first h_m rounds
                # of its max_gap window and idles the rest (the batched
                # engine's masked-step scan leaves w_hat bitwise untouched
                # on idle rounds; skipping the step here matches that)
                if (not self.per_device
                        or t - self.win_start[m] < self.decisions[m].h):
                    batch = self._sample_batch(m, t)
                    self.w_hat[m] = self._sgd_step(self.w_hat[m], batch,
                                                   jnp.float32(eta))
                if t + 1 >= self.next_sync[m]:
                    g, total, t32 = self._sync_device(m, t)
                    updates.append(g)
                    sync_ms.append(m)
                    walls.append(total["time_s"])
                    t32s.append(t32)
            if updates:
                if self.agg.name == "mean":
                    g_mean = sum(updates) / self.m_devices
                    flat = flatten_tree(self.params) - g_mean
                    self.params = unflatten_like(flat, self.params)
                    self.server_wall_s += max(walls)
                elif self.agg.name == "diloco":
                    self._apply_server_nonmean(updates, sync_ms, t32s, 1.0)
                    self.server_wall_s += max(walls)
                else:  # semi_sync: the server never waits past the deadline
                    deadline = self._window_deadline(sync_ms)
                    self._apply_server_nonmean(updates, sync_ms, t32s,
                                               deadline)
                    self.server_wall_s += min(deadline, max(walls))
                for m in sync_ms:
                    # broadcast: device adopts the global model
                    self.w_hat[m] = self.params
                    self.w_anchor[m] = flatten_tree(self.params)
                self._update_chan_state(self.scen_carry)
                self._observe_devices(sync_ms, t)
                self._decide_devices(sync_ms, t + 1)
            if t % cfg.eval_every == 0 or t == cfg.rounds - 1:
                self._record(hist, t)
        return hist

    def _layer_slices(self) -> list[tuple[str, int, int]]:
        """(name, lo, hi) flat slices of the model layers, cached."""
        if not hasattr(self, "_layer_slices_cache"):
            self._layer_slices_cache = tree_layer_slices(self.params)
        return self._layer_slices_cache

    def _ef_step(self, m: int, t: int, delta: Array, ks: Sequence[int],
                 received: Sequence[bool]) -> Array:
        """One error-compensated layered compression (backend-dispatched).

        ``cfg.layer_policy != "global"`` prepends the per-model-layer
        candidate mask (:mod:`repro.core.compressor` per-layer section):
        budgets reshape WHICH coordinates compete for the channel layers,
        and error feedback still accumulates u - g, so no update mass is
        lost.  Semantics match the batched engine's ``compress`` exactly
        (the loop~batched rung of the ladder holds per policy)."""
        policy = self.cfg.layer_policy
        if policy != "global":
            slices = self._layer_slices()
            ks_arr = jnp.asarray(ks, jnp.int32)
            u = self.ef[m].e + delta
            if self.backend == "pallas":
                from repro.kernels import lgc_compress_hist
                b = layer_budgets(policy, u, slices, jnp.sum(ks_arr), self.d)
                mask = per_layer_candidates_hist(u, slices, b)
                g, _ = lgc_compress_hist(
                    jnp.zeros_like(u), jnp.where(mask, u, 0.0),
                    jnp.cumsum(ks_arr), jnp.asarray(received, jnp.int32))
            else:
                g = per_layer_compress(u, ks_arr, jnp.asarray(received),
                                       slices, policy, self.d)
            self.ef[m] = EFState(u - g)
            return g
        if self.backend == "pallas":
            from repro.kernels import lgc_compress_hist
            cum_ks = jnp.cumsum(jnp.asarray(ks, jnp.int32))
            recv = jnp.asarray(received, jnp.int32)
            g, e_new = lgc_compress_hist(self.ef[m].e, delta, cum_ks, recv)
            self.ef[m] = EFState(e_new)
            return g
        comp = LGCCompressor(ks)
        g, self.ef[m] = ef_compress(self.ef[m], delta, comp, received)
        return g

    def _sync_device(self, m: int, t: int):
        dec = self.decisions[m]
        k_ch = stream_key(self._base, TAG_CHANNEL, t, m)
        carry_m = jax.tree_util.tree_map(lambda a: a[m], self.scen_carry)
        ch = sample_from_carry(self.scenario, self._consts, carry_m, k_ch)
        if self.scenario.has_dropout:
            # dropped sync: the whole uplink is lost (EF keeps the mass),
            # the downlink broadcast below still reaches the device; same
            # dropout_mask the batched engine applies, on this device's row
            drop = dropout_mask(self.scenario, self._base, t,
                                self._dev_ids[m:m + 1])[0]
            ch = ch._replace(up=ch.up & ~drop)
        delta = self.w_anchor[m] - flatten_tree(self.w_hat[m])  # w_m - w_hat^{t+1/2}

        if self.mode == "lgc_q8":
            # LGC + QSGD int8 values on the wire (composes under EF):
            # wire = k * (1 value byte + 4 index bytes) per channel
            ks = list(dec.ks)
            received = [bool(u) for u in np.asarray(ch.up)][:len(ks)]
            received += [True] * (len(ks) - len(received))
            g = self._ef_step(m, t, delta, ks, received)
            from .compressor import qsgd_dequantize, qsgd_quantize
            kq = stream_key(self._base, TAG_QUANT, t, m)
            q, scale = qsgd_quantize(g, kq)
            g_deq = qsgd_dequantize(q, scale)
            # quantization residual stays in the error memory
            self.ef[m] = EFState(self.ef[m].e + (g - g_deq))
            g = g_deq
            nbytes = wire_bytes(ks, 1, self.cfg.index_bytes)
            nbytes = [b if r else 0 for b, r in zip(nbytes, received)]
            cost = comm_cost(ch, nbytes)
        elif self.mode == "fedavg":
            # dense, no error feedback; full model over the single fastest
            # *up* channel -- with every channel down the upload is lost
            # (no bytes, no update; FedAvg carries nothing over)
            any_up = bool(np.asarray(ch.up).any())
            g = jnp.where(any_up, delta, 0.0)
            bw = np.asarray(ch.bandwidth_mb_s) * np.asarray(ch.up)
            best = int(np.argmax(bw))
            nbytes = [0] * len(self.cfg.channels)
            nbytes[best] = self.d * self.cfg.value_bytes if any_up else 0
            cost = comm_cost(ch, nbytes)
        else:
            if self.mode == "topk":
                ks = [sum(dec.ks)] + [0] * (len(dec.ks) - 1)
            else:
                ks = list(dec.ks)
            received = [bool(u) for u in np.asarray(ch.up)][:len(ks)]
            received += [True] * (len(ks) - len(received))
            g = self._ef_step(m, t, delta, ks, received)
            nbytes = wire_bytes(ks, self.cfg.value_bytes, self.cfg.index_bytes)
            nbytes = [b if r else 0 for b, r in zip(nbytes, received)]
            cost = comm_cost(ch, nbytes)

        ccomp = comp_cost(self.profiles[m], dec.h)
        total = {
            "energy_j": float(cost["energy_j"]) + ccomp["energy_j"],
            "money": float(cost["money"]) + ccomp["money"],
            "time_s": float(cost["time_s"]) + ccomp["time_s"],
            "mb": float(sum(nbytes)) / 1e6,
        }
        for k, v in total.items():
            self.spend[m][k] += v
        # f32 window time (comm + compute) exactly as the batched window
        # traces it -- the semi-sync staleness input
        t32 = np.float32(np.float32(cost["time_s"])
                         + np.float32(ccomp["time_s"]))
        return g, total, t32

    def _observe_devices(self, ms: Sequence[int], t: int, params=None):
        """Reward Eq. (14)-(16): utility = (loss drop) / (resource spend),
        delivered to every synced reward-seeking device in one fleet call.
        ``params`` as in :meth:`_reward_losses`."""
        need = [m for m in ms if self.fleet.needs_reward[m]]
        if not need:
            return
        loss_drops = np.zeros(self.m_devices, np.float64)
        mask = np.zeros(self.m_devices, bool)
        for m, loss in zip(need, self._reward_losses(need, t, params)):
            if self.prev_loss[m] is not None:
                loss_drops[m] = self.prev_loss[m] - loss
                mask[m] = True
            self.prev_loss[m] = loss
        if mask.any():
            self.fleet.observe(loss_drops, self._controller_states(), mask)

    def _record(self, hist: History, t: int):
        loss, acc = self._eval_subset(TAG_EVAL, (t,), 2048)
        hist.step.append(t)
        hist.loss.append(loss)
        hist.accuracy.append(acc)
        hist.energy_j.append(sum(s["energy_j"] for s in self.spend))
        hist.money.append(sum(s["money"] for s in self.spend))
        hist.time_s.append(max(s["time_s"] for s in self.spend))
        hist.uplink_mb.append(sum(s["mb"] for s in self.spend))
        hist.server_wall_s.append(self.server_wall_s)


def run_baseline(task: FLTask, cfg: FLConfig, mode: str,
                 h: int = 4, ks: Sequence[int] | None = None,
                 engine: str | None = None, backend: str | None = None,
                 mesh=None, server_reduce: str = "gather") -> History:
    """Convenience: FedAvg / LGC-noDRL / Top-k with fixed controllers."""
    m = len(task.device_data)
    if ks is None:
        d = tree_size(task.init(jax.random.PRNGKey(0)))
        k_total = max(1, d // 20)                      # 5% sparsity default
        ks = [k_total // 2, k_total // 4, k_total - k_total // 2 - k_total // 4]
    ctrls = [FixedController(h, ks) for _ in range(m)]
    return LGCSimulator(task, cfg, ctrls, mode=mode,
                        engine=engine, backend=backend,
                        mesh=mesh, server_reduce=server_reduce).run()
