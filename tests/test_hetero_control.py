"""Heterogeneous device control (action_space="per_device"): the
equivalence ladder, the pipelined-decision path, and cost conservation.

The tentpole invariants of docs/ARCHITECTURE.md §13:

* with every device on a *different* ``(h_m, ks_m)``, the masked-step scan
  keeps loop~batched allclose and batched==sharded BIT-identical at every
  buildable mesh size, static and gilbert_flaky;
* the degeneracy pin: homogeneous actions (h_m = max_gap, one ks for all,
  full batteries) reproduce the pre-§13 shared-space History asdict-equal --
  i.e. the new action space costs the default path nothing;
* ``pipeline_decisions=True`` only *re-times* controller work: with a
  stateless fleet the History is identical, and the pipelined ladder holds
  end to end;
* cost conservation: total energy_j / money / time_s / mb spend is the same
  across all three engines and equals :func:`repro.core.audit
  .recompute_spend` replayed from the decision log alone -- accounting
  drift in any engine now fails here instead of skewing BENCH Pareto rows.
"""
import dataclasses
import math

import jax
import numpy as np
import pytest

from repro.core import (FLConfig, FixedController, LGCSimulator,
                        audit_simulator, make_fleet_ddpg, recompute_spend)
from repro.launch.mesh import make_host_mesh
from repro.models.paper_models import make_mnist_task

N_DEV = len(jax.devices())
SHARD_COUNTS = sorted({1, N_DEV})
M = 8

# every device gets a different (h_m, ks_m): step counts sweep the whole
# [1, max_gap] range and budgets skew across channels
HS = [1, 2, 3, 4, 5, 6, 7, 8]
KSS = [[60, 30, 10], [10, 60, 30], [30, 10, 60], [80, 10, 10],
       [10, 80, 10], [10, 10, 80], [34, 33, 33], [5, 5, 90]]

_TASK = {}


def _task(scn: str):
    if scn not in _TASK:
        _TASK[scn] = make_mnist_task("lr", m_devices=M, n_train=1600,
                                     scenario=scn)
    return _TASK[scn]


def _cfg(scn: str, **kw) -> FLConfig:
    return FLConfig(rounds=24, eval_every=8, max_gap=8, scenario=scn,
                    action_space="per_device", **kw)


class ScriptedFleet:
    """Fleet-protocol controller that replays fixed per-device decisions --
    heterogeneous actions without DDPG nondeterminism in the ladder."""

    def __init__(self, hs, kss):
        self.m = len(hs)
        self.hs = list(hs)
        self.kss = [list(k) for k in kss]
        self.needs_reward = np.zeros(self.m, bool)

    def act(self, states, mask=None):
        return np.asarray(self.hs, np.int64), [list(k) for k in self.kss]

    def observe(self, *a, **k):
        pass


def _run(scn: str, engine: str, *, pipeline=False, mesh=None, mode="lgc"):
    cfg = _cfg(scn, pipeline_decisions=pipeline)
    sim = LGCSimulator(_task(scn), cfg, ScriptedFleet(HS, KSS), mode=mode,
                      engine=engine, mesh=mesh)
    return sim, sim.run()


class TestHeteroLadder:
    @pytest.mark.parametrize("scn", ["static", "gilbert_flaky"])
    def test_loop_matches_batched(self, scn):
        _, h_loop = _run(scn, "loop")
        _, h_bat = _run(scn, "batched")
        assert h_loop.step == h_bat.step
        np.testing.assert_allclose(h_bat.loss, h_loop.loss, atol=1e-4)
        np.testing.assert_allclose(h_bat.accuracy, h_loop.accuracy,
                                   atol=1e-4)
        np.testing.assert_allclose(h_bat.uplink_mb, h_loop.uplink_mb,
                                   atol=1e-4)
        np.testing.assert_allclose(h_bat.energy_j, h_loop.energy_j,
                                   rtol=1e-5)
        np.testing.assert_allclose(h_bat.time_s, h_loop.time_s, rtol=1e-5)

    @pytest.mark.parametrize("n_shards", SHARD_COUNTS)
    @pytest.mark.parametrize("scn", ["static", "gilbert_flaky"])
    def test_batched_matches_sharded_bitwise(self, scn, n_shards):
        """Heterogeneous (h_m, ks_m) shard with the device axis: the
        masked-step predicate is per-row data, so the shard layout cannot
        change a single float."""
        _, h_bat = _run(scn, "batched")
        _, h_sh = _run(scn, "sharded", mesh=make_host_mesh(n_shards))
        assert h_sh.asdict() == h_bat.asdict()

    @pytest.mark.parametrize("scn", ["static", "gilbert_flaky"])
    def test_pipelined_identical_for_stateless_fleet(self, scn):
        """pipeline_decisions only re-times when the fleet acts/observes;
        a stateless fleet makes the same decisions either way, so the
        History must be bitwise unchanged -- on every engine."""
        for engine in ("loop", "batched"):
            _, h0 = _run(scn, engine, pipeline=False)
            _, h1 = _run(scn, engine, pipeline=True)
            assert h1.asdict() == h0.asdict(), engine

    @pytest.mark.parametrize("n_shards", SHARD_COUNTS)
    def test_pipelined_sharded_bitwise(self, n_shards):
        _, h_bat = _run("gilbert_flaky", "batched", pipeline=True)
        _, h_sh = _run("gilbert_flaky", "sharded", pipeline=True,
                       mesh=make_host_mesh(n_shards))
        assert h_sh.asdict() == h_bat.asdict()

    @pytest.mark.parametrize("pipeline", [False, True])
    @pytest.mark.parametrize("engine", ["loop", "batched", "sharded"])
    def test_homogeneous_degeneracy_pin(self, engine, pipeline):
        """h_m = max_gap for all devices makes the per_device window the
        shared window: the History must equal the pre-§13 shared path
        asdict-exactly (the ladder's bitwise anchor for the refactor)."""
        scn = "gilbert_flaky"
        ks = [60, 30, 30]
        shared_cfg = FLConfig(rounds=24, eval_every=8, max_gap=8,
                              scenario=scn)
        ctrls = [FixedController(8, ks) for _ in range(M)]
        h_shared = LGCSimulator(_task(scn), shared_cfg, ctrls, mode="lgc",
                                engine=engine).run()
        pd_cfg = _cfg(scn, pipeline_decisions=pipeline)
        ctrls = [FixedController(8, ks) for _ in range(M)]
        h_pd = LGCSimulator(_task(scn), pd_cfg, ctrls, mode="lgc",
                            engine=engine).run()
        assert h_pd.asdict() == h_shared.asdict()

    def test_heterogeneous_h_changes_compute_not_sync(self):
        """Devices with small h_m really do idle: same sync cadence (all
        sync each max_gap rounds), lower compute energy than full-steppers
        with the same profile."""
        sim, _ = _run("static", "batched")
        commits = {}
        for (t, m, h, ks) in sim.decision_log:
            commits.setdefault(m, []).append((t, h))
        for m in range(M):
            ts = [t for t, _h in commits[m]]
            assert ts == list(range(0, 24 + 1, 8))[: len(ts)]
            assert all(h == HS[m] for _t, h in commits[m])
        # compute energy scales with h_m and ONLY h_m: re-pricing device 0's
        # log with h=8 instead of h=1 must add exactly (8-1) steps x
        # comp_j_per_step x (3 completed windows) -- comm costs untouched
        rec = recompute_spend(sim.cfg, sim.mode, sim.d, sim.decision_log,
                              M, profiles=sim.profiles)
        fat = [(t, m, 8 if m == 0 else h, ks)
               for (t, m, h, ks) in sim.decision_log]
        rec8 = recompute_spend(sim.cfg, sim.mode, sim.d, fat, M,
                               profiles=sim.profiles)
        gap = rec8[0]["energy_j"] - rec[0]["energy_j"]
        assert gap == pytest.approx(
            (8 - HS[0]) * sim.profiles[0].comp_j_per_step * 3)
        assert rec8[0]["mb"] == rec[0]["mb"]


class TestCostConservation:
    @pytest.mark.parametrize("scn", ["static", "gilbert_flaky"])
    def test_ledger_matches_decision_log_replay(self, scn):
        """Every engine's live spend ledger equals the audit recompute from
        (config, decision log) alone: EXACT for the loop engine (identical
        host float path), f32-ulp-tight for the in-program engines (their
        fused window cost sums differ from the eager channel math by
        FMA/reassociation only)."""
        for engine in ("loop", "batched", "sharded"):
            sim, _ = _run(scn, engine)
            rec, live = audit_simulator(sim)
            if engine == "loop":
                assert rec == live
                continue
            for m in range(M):
                for k in ("energy_j", "money", "time_s", "mb"):
                    assert math.isclose(rec[m][k], live[m][k],
                                        rel_tol=1e-6, abs_tol=1e-12), (
                        engine, m, k)

    def test_totals_identical_across_engines(self):
        """Cross-engine conservation: the three engines bill the same
        totals for the same decisions (batched==sharded bitwise; the loop
        engine to float tolerance of the f32 channel math)."""
        sims = {e: _run("gilbert_flaky", e)[0]
                for e in ("loop", "batched", "sharded")}
        sp = {e: s.spend for e, s in sims.items()}
        assert sp["batched"] == sp["sharded"]
        for m in range(M):
            for k in ("energy_j", "money", "time_s", "mb"):
                assert math.isclose(sp["loop"][m][k], sp["batched"][m][k],
                                    rel_tol=1e-6), (m, k)
        logs = {e: s.decision_log for e, s in sims.items()}
        assert logs["loop"] == logs["batched"] == logs["sharded"]

    def test_shared_space_ddpg_audits_clean(self):
        """The auditor also covers the shared action space with a learning
        fleet (heterogeneous next_sync windows, DDPG-chosen budgets)."""
        task = _task("gilbert_flaky")
        cfg = FLConfig(rounds=20, eval_every=10, max_gap=6,
                       scenario="gilbert_flaky")
        fleet = make_fleet_ddpg(M, 7850, h_max=6, seed=3)
        sim = LGCSimulator(task, cfg, fleet, mode="lgc", engine="batched")
        sim.run()
        rec, live = audit_simulator(sim)
        for m in range(M):
            for k in ("energy_j", "money", "time_s", "mb"):
                assert math.isclose(rec[m][k], live[m][k],
                                    rel_tol=1e-6, abs_tol=1e-12), (m, k)

    @pytest.mark.parametrize("mode", ["topk", "lgc_q8", "fedavg"])
    def test_other_modes_audit_clean(self, mode):
        """The byte accounting differs per mode (folded budgets, int8
        values, dense best-channel) -- the replay must price each the same
        way the engines do."""
        sim, _ = _run("gilbert_flaky", "batched", mode=mode)
        rec, live = audit_simulator(sim)
        for m in range(M):
            for k in ("energy_j", "money", "time_s", "mb"):
                assert math.isclose(rec[m][k], live[m][k],
                                    rel_tol=1e-6, abs_tol=1e-12), (m, k)

    def test_tampered_log_fails_audit(self):
        """The property has teeth: perturbing one logged decision breaks
        the ledger match."""
        sim, _ = _run("gilbert_flaky", "batched")
        t, m, h, ks = sim.decision_log[0]
        bad = list(sim.decision_log)
        bad[0] = (t, m, h, tuple(k + 8 for k in ks))
        rec = recompute_spend(sim.cfg, sim.mode, sim.d, bad, M,
                              profiles=sim.profiles)
        assert any(rec[m][k] != sim.spend[m][k]
                   for k in ("energy_j", "mb"))


class TestHeteroFleetScenario:
    def test_profiles_skewed_and_shard_independent(self):
        from repro.core import get_scenario
        scn = get_scenario("hetero_fleet")
        profs = scn.device_profiles(M)
        batteries = [p.battery for p in profs]
        mults = [p.comp_time_per_step_s / profs[0].comp_time_per_step_s
                 for p in profs]
        assert len(set(batteries)) > 1 and len(set(round(m, 3)
                                                   for m in mults)) > 1
        # cycled by global id: device i and i + len(ladder) share traits
        period = len(scn.hetero.batteries)
        assert batteries[0] == batteries[0 + period]
        assert mults[1] == mults[1 + period]
        # the weak tail exists: at least one device's battery clamp bites
        # below h_max=4 (cap = 1 + floor(soc * 3) < 4 needs soc < 1)
        assert min(batteries) < 1.0

    @pytest.mark.parametrize("n_shards", SHARD_COUNTS)
    def test_hetero_fleet_ladder(self, n_shards):
        """The new registry scenario rides the per_device ladder: skewed
        profiles reach the observation vector and the cost model without
        breaking batched==sharded bitwise."""
        _, h_bat = _run("hetero_fleet", "batched", pipeline=True)
        _, h_sh = _run("hetero_fleet", "sharded", pipeline=True,
                       mesh=make_host_mesh(n_shards))
        assert h_sh.asdict() == h_bat.asdict()

    def test_per_device_ddpg_end_to_end(self):
        """A real per_device DDPG fleet on hetero_fleet: profile-augmented
        observations flow, battery clamps bind, and the run learns
        something (loss drops) while logging per-device decisions."""
        task = _task("hetero_fleet")
        cfg = _cfg("hetero_fleet", pipeline_decisions=True)
        fleet = make_fleet_ddpg(M, 7850, action_space="per_device", seed=1)
        sim = LGCSimulator(task, cfg, fleet, mode="lgc", engine="batched")
        hist = sim.run()
        assert hist.loss[-1] < hist.loss[0]
        # the battery clamp binds: devices on the weak-tail traits (battery
        # 0.7 / 0.67) may never exceed their 1 + floor(soc * 7) step cap
        for (t, m, h, ks) in sim.decision_log:
            cap = 1 + int(np.floor(sim.profiles[m].battery * 7))
            assert 1 <= h <= cap, (m, h, cap)
            assert sum(ks) <= fleet.cfg.k_total_max
