"""GLM-4-9B [hf:THUDM/glm-4-9b] -- dense, RoPE, GQA kv=2, QKV bias."""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b", arch_type="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab_size=151_552,
    qkv_bias=True, mlp="swiglu", norm="rmsnorm",
    fsdp=True,
    source="hf:THUDM/glm-4-9b",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="glm4-9b-smoke", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=2, d_ff=512, vocab_size=512, fsdp=False, remat=False,
        attn_q_chunk=64)
