"""Serving example: batched greedy decoding with a KV cache.

Prefills a batch of prompts through a small qwen2-family model, then decodes
tokens with the same serve_step the decode_32k / long_500k dry-runs lower
(including the sliding-window ring cache used at long context).

  PYTHONPATH=src python examples/serve_decode.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.launch.steps import make_serve_step
from repro.models import transformer as tf


def main():
    cfg = dataclasses.replace(get_smoke_config("qwen2-1.5b"), remat=False)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    b, prompt_len, gen = 4, 24, 24

    prompts = jax.random.randint(jax.random.PRNGKey(1), (b, prompt_len), 0,
                                 cfg.vocab_size)
    # ---- prefill ---------------------------------------------------------
    cache_len = prompt_len + gen
    logits, cache = tf.prefill(params, cfg, {"tokens": prompts}, cache_len)
    next_tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    print(f"prefilled {b} prompts of {prompt_len} tokens")

    # ---- decode loop -----------------------------------------------------
    serve = jax.jit(make_serve_step(cfg))
    toks = [next_tok]
    t0 = time.time()
    for _ in range(gen - 1):
        next_tok, cache = serve(params, next_tok, cache)
        toks.append(next_tok)
    out = jnp.concatenate(toks, 1)
    dt = time.time() - t0
    print(f"decoded {gen} tokens/seq x {b} seqs in {dt:.2f}s "
          f"({b*gen/dt:.1f} tok/s on CPU)")
    print("generated token ids (seq 0):", out[0].tolist())

    # ---- sliding-window variant (the long_500k path) ---------------------
    window = 16
    wcache = tf.init_cache(cfg, b, window)
    wcache["pos"] = jnp.int32(0)
    serve_w = jax.jit(make_serve_step(cfg, window=window))
    tok = prompts[:, :1]
    for _ in range(40):                        # runs past the window size
        tok, wcache = serve_w(params, tok, wcache)
    print(f"ring-buffer decode OK: pos={int(wcache['pos'])} > window={window}")
    assert int(wcache["pos"]) == 40


if __name__ == "__main__":
    main()
