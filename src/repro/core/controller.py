"""Learning-based control algorithm (paper §3): per-device DDPG.

Each device runs its own agent deciding, at every synchronization, its
  * H_m      -- number of local computation steps until the next sync
  * D_{m,n}  -- gradient entries allocated to channel n (the LGC layer sizes)

State  (Eq. 11-12): per-resource communication/computation consumption.
Action (Eq. 13):    a = (H, D_1..D_N), continuous, squashed by tanh.
Reward (Eq. 14-16): weighted ratio of utility U = (loss drop)/(spend).

DDPG (Lillicrap et al. 2015): deterministic actor pi(s|theta_pi), critic
Q(s,a|theta_Q), replay buffer, soft target networks, Gaussian exploration
noise.  Pure JAX (MLPs + Adam from repro.optim), numpy ring replay buffer.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fl import RoundDecision
from repro.optim.optimizers import (OptimizerConfig, adamw_init, adamw_update,
                                    apply_updates)

Array = jax.Array


# ---------------------------------------------------------------------------
# tiny MLPs
# ---------------------------------------------------------------------------

def _mlp_init(key, sizes):
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        k1, key = jax.random.split(key)
        params.append({"w": jax.random.normal(k1, (a, b)) * (2 / a) ** 0.5,
                       "b": jnp.zeros((b,))})
    return params


def _mlp_apply(params, x, final_tanh=False):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return jnp.tanh(x) if final_tanh else x


# ---------------------------------------------------------------------------
# DDPG agent
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DDPGConfig:
    state_dim: int = 4           # energy, money, time, mb  (per Eq. 11)
    n_channels: int = 3
    h_max: int = 8               # cap on local steps (paper's H bound)
    k_total_max: int = 0         # max coords/round; set from model size
    hidden: int = 64
    gamma: float = 0.95          # discount (paper's gamma_m)
    tau: float = 0.01            # soft target update
    buffer_size: int = 4096
    batch_size: int = 64
    noise_sigma: float = 0.2
    noise_decay: float = 0.999
    lr: float = 1e-3
    seed: int = 0


class ReplayBuffer:
    def __init__(self, capacity: int, state_dim: int, action_dim: int):
        self.s = np.zeros((capacity, state_dim), np.float32)
        self.a = np.zeros((capacity, action_dim), np.float32)
        self.r = np.zeros((capacity,), np.float32)
        self.s2 = np.zeros((capacity, state_dim), np.float32)
        self.n, self.idx, self.capacity = 0, 0, capacity

    def add(self, s, a, r, s2):
        i = self.idx
        self.s[i], self.a[i], self.r[i], self.s2[i] = s, a, r, s2
        self.idx = (i + 1) % self.capacity
        self.n = min(self.n + 1, self.capacity)

    def sample(self, rng: np.random.Generator, batch: int):
        idx = rng.integers(0, self.n, batch)
        return self.s[idx], self.a[idx], self.r[idx], self.s2[idx]


class DDPGController:
    """Implements the fl.py controller interface (act / reward)."""

    def __init__(self, cfg: DDPGConfig):
        self.cfg = cfg
        self.action_dim = 1 + cfg.n_channels
        key = jax.random.PRNGKey(cfg.seed)
        ka, kc = jax.random.split(key)
        self.actor = _mlp_init(ka, [cfg.state_dim, cfg.hidden, cfg.hidden,
                                    self.action_dim])
        self.critic = _mlp_init(kc, [cfg.state_dim + self.action_dim,
                                     cfg.hidden, cfg.hidden, 1])
        self.actor_t = jax.tree_util.tree_map(jnp.copy, self.actor)
        self.critic_t = jax.tree_util.tree_map(jnp.copy, self.critic)
        ocfg = OptimizerConfig(lr=cfg.lr, warmup_steps=1, weight_decay=0.0)
        self._ocfg = ocfg
        self.opt_a = adamw_init(self.actor)
        self.opt_c = adamw_init(self.critic)
        self.buffer = ReplayBuffer(cfg.buffer_size, cfg.state_dim,
                                   self.action_dim)
        self._rng = np.random.default_rng(cfg.seed)
        self.sigma = cfg.noise_sigma
        self._last: tuple | None = None     # (state, raw_action)
        self.critic_losses: list[float] = []
        self.rewards: list[float] = []
        self._train_step = jax.jit(self._make_train_step())

    # -- controller interface -------------------------------------------
    def act(self, state: np.ndarray) -> RoundDecision:
        s = self._norm_state(state)
        a = np.asarray(_mlp_apply(self.actor, jnp.asarray(s),
                                  final_tanh=True))
        a = a + self._rng.normal(0, self.sigma, a.shape)
        a = np.clip(a, -1, 1)
        self.sigma *= self.cfg.noise_decay
        self._last = (s, a.astype(np.float32))
        return self._to_decision(a)

    def reward(self, loss_drop: float, new_state: np.ndarray):
        """Called by the simulator after the round (Eq. 14-16 computed here
        from loss drop and the *incremental* spend recorded in the state)."""
        if self._last is None:
            return
        s, a = self._last
        s2 = self._norm_state(new_state)
        spend = float(np.sum(np.maximum(s2 - s, 1e-6)))
        r = float(np.clip(loss_drop / spend, -10.0, 10.0))
        self.rewards.append(r)
        self.buffer.add(s, a, r, s2)
        self._last = None
        if self.buffer.n >= self.cfg.batch_size:
            self._learn()

    # -- internals --------------------------------------------------------
    def _norm_state(self, state: np.ndarray) -> np.ndarray:
        # log-scale resources so the MLP sees O(1) numbers
        return np.log1p(np.maximum(state, 0)).astype(np.float32)

    def _to_decision(self, a: np.ndarray) -> RoundDecision:
        cfg = self.cfg
        h = int(round((a[0] + 1) / 2 * (cfg.h_max - 1))) + 1
        # channel allocations: softmax-ish positive split of the budget
        w = np.exp(2.0 * a[1:])
        w = w / w.sum()
        k_total = max(cfg.n_channels, cfg.k_total_max)
        ks = np.maximum((w * k_total).astype(int), 1)
        return RoundDecision(h, [int(k) for k in ks])

    def _make_train_step(self):
        cfg = self.cfg

        def critic_loss(critic, actor_t, critic_t, s, a, r, s2):
            a2 = _mlp_apply(actor_t, s2, final_tanh=True)
            q_next = _mlp_apply(critic_t, jnp.concatenate([s2, a2], -1))[:, 0]
            y = r + cfg.gamma * q_next                       # Eq. (18)
            q = _mlp_apply(critic, jnp.concatenate([s, a], -1))[:, 0]
            return jnp.mean((q - jax.lax.stop_gradient(y)) ** 2)

        def actor_loss(actor, critic, s):
            a = _mlp_apply(actor, s, final_tanh=True)
            q = _mlp_apply(critic, jnp.concatenate([s, a], -1))
            return -jnp.mean(q)

        def step(actor, critic, actor_t, critic_t, opt_a, opt_c, s, a, r, s2):
            cl, gc = jax.value_and_grad(critic_loss)(critic, actor_t,
                                                     critic_t, s, a, r, s2)
            upd, opt_c = adamw_update(self._ocfg, gc, opt_c, critic)
            critic = apply_updates(critic, upd)
            al, ga = jax.value_and_grad(actor_loss)(actor, critic, s)
            upd, opt_a = adamw_update(self._ocfg, ga, opt_a, actor)
            actor = apply_updates(actor, upd)
            soft = lambda t, o: jax.tree_util.tree_map(
                lambda x, y: (1 - cfg.tau) * x + cfg.tau * y, t, o)
            return actor, critic, soft(actor_t, actor), soft(critic_t, critic), \
                opt_a, opt_c, cl

        return step

    def _learn(self):
        s, a, r, s2 = self.buffer.sample(self._rng, self.cfg.batch_size)
        (self.actor, self.critic, self.actor_t, self.critic_t,
         self.opt_a, self.opt_c, cl) = self._train_step(
            self.actor, self.critic, self.actor_t, self.critic_t,
            self.opt_a, self.opt_c,
            jnp.asarray(s), jnp.asarray(a), jnp.asarray(r), jnp.asarray(s2))
        self.critic_losses.append(float(cl))


def make_ddpg_controllers(m_devices: int, model_dim: int,
                          n_channels: int = 3, h_max: int = 8,
                          sparsity: float = 0.05, seed: int = 0
                          ) -> list[DDPGController]:
    """One agent per device (paper: per-device policies)."""
    return [DDPGController(DDPGConfig(
        n_channels=n_channels, h_max=h_max,
        k_total_max=max(n_channels, int(model_dim * sparsity)),
        seed=seed + 17 * m)) for m in range(m_devices)]
