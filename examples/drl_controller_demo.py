"""DRL control demo (paper §3): a DDPG fleet adapts H and the
layer-to-channel allocation as channel conditions shift mid-training.

Halfway through, the 5G channel becomes unreliable and expensive; the
learned controller bank shifts traffic toward the cheaper channels while
the fixed controller keeps paying.  All M agents act, observe and train
through one jitted fleet call per sync boundary (FleetDDPG).

  PYTHONPATH=src python examples/drl_controller_demo.py
"""
import jax
import numpy as np

from repro.core import (FixedController, FLConfig, LGCSimulator,
                        make_fleet_ddpg, tree_size)
from repro.core.channels import DEFAULT_CHANNELS, ChannelSpec

from repro.models.paper_models import make_mnist_task

DEGRADED = (
    DEFAULT_CHANNELS[0],
    DEFAULT_CHANNELS[1],
    ChannelSpec("5G-degraded",
                DEFAULT_CHANNELS[2].energy_mean_j_per_mb * 3,
                DEFAULT_CHANNELS[2].energy_std,
                DEFAULT_CHANNELS[2].bandwidth_mb_s * 0.2,
                DEFAULT_CHANNELS[2].money_per_mb * 4, 0.6),
)


def run_phase(task, ctrls, channels, rounds, mode="lgc"):
    cfg = FLConfig(rounds=rounds, eval_every=rounds // 2, channels=channels)
    sim = LGCSimulator(task, cfg, ctrls, mode=mode)
    h = sim.run()
    return h, sim


# a representative spend state to probe the learned policies with
PROBE = np.tile(np.array([1e3, 0.01, 10, 1], np.float32), (3, 1))


def print_allocation(fleet, states):
    """The public greedy-policy API: no exploration noise, no stream use."""
    h, ks = fleet.allocation(states)
    for m in range(fleet.m):
        frac = ks[m] / ks[m].sum()
        trend = np.mean(fleet.rewards[m][-5:]) if fleet.rewards[m] else 0.0
        print(f"  device {m}: H={int(h[m])} channel split "
              f"3G={frac[0]:.2f} 4G={frac[1]:.2f} 5G={frac[2]:.2f} "
              f"(reward trend {trend:+.3f})")


def main():
    task = make_mnist_task("lr", m_devices=3, n_train=2000)
    d = tree_size(task.init(jax.random.PRNGKey(0)))

    print("== phase 1: nominal channels (3G/4G/5G) ==")
    fleet = make_fleet_ddpg(3, d)
    h1, _ = run_phase(task, fleet, DEFAULT_CHANNELS, 80)
    print(f"  loss {h1.loss[-1]:.3f}, energy {h1.energy_j[-1]:.0f} J")
    print_allocation(fleet, PROBE)

    print("== phase 2: 5G degraded (3x energy, 4x money, 60% uptime) ==")
    h2, _ = run_phase(task, fleet, DEGRADED, 80)
    print(f"  loss {h2.loss[-1]:.3f}, energy {h2.energy_j[-1]:.0f} J")

    fixed = [FixedController(4, [d // 60, d // 40, d // 40])
             for _ in range(3)]
    h3, _ = run_phase(task, fixed, DEGRADED, 80)
    print(f"== fixed controller under degraded channels: "
          f"energy {h3.energy_j[-1]:.0f} J ==")

    # learned allocation after adaptation
    print_allocation(fleet, PROBE)
    print("\nThe DDPG fleet steers allocation away from the degraded 5G "
          "channel (paper §3 behaviour).")


if __name__ == "__main__":
    main()
