"""Jitted public wrappers around the Pallas compression kernels.

``lgc_compress_hist`` is the end-to-end histogram-LGC pipeline used by the
distributed training step and the benchmarks:

  1. maxabs (Pallas, pass 1)
  2. 256-bin magnitude histogram of u = e + delta (Pallas, pass 2)
  3. per-layer thresholds from the CDF (host, 256 scalars)
  4. fused layered-sparsify + error-feedback (Pallas, pass 3)

Matches :func:`repro.kernels.ref.hist_lgc_compress` exactly (same bins and
edges); validated in tests/test_kernels.py across shapes and dtypes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .layered_sparsify import sparsify_ef
from .topk_threshold import histogram, maxabs, thresholds_from_counts


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def lgc_compress_hist(e: jax.Array, delta: jax.Array, cum_ks: jax.Array,
                      received: jax.Array, *, block_rows: int = 64,
                      interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Histogram-LGC with error feedback. Returns (g, e_new), f32 (D,)."""
    u = None  # never materialised in HBM; kernels recompute e + delta
    del u
    # statistics passes operate on u = e + delta; compute it blockwise too by
    # passing the sum lazily -- for stats we accept one fused add here since
    # XLA fuses it into the pallas input copy.
    u_stats = (e.astype(jnp.float32) + delta.astype(jnp.float32))
    m = maxabs(u_stats, block_rows=block_rows, interpret=interpret)
    counts = histogram(u_stats, m, block_rows=block_rows, interpret=interpret)
    thr = thresholds_from_counts(counts, m, cum_ks)
    return sparsify_ef(e, delta, thr, received, block_rows=block_rows,
                       interpret=interpret)


@jax.jit
def lgc_compress_hist_ref(e, delta, cum_ks, received):
    """Oracle path (pure jnp), exported for benchmarks."""
    return ref.hist_lgc_compress(e, delta, cum_ks, received)


def selected_counts(g: jax.Array) -> jax.Array:
    """Number of transmitted coordinates (for wire-byte accounting)."""
    return jnp.sum((g != 0).astype(jnp.int32))
