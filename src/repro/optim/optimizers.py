"""Pytree optimizers implemented from scratch (container has no optax).

All states are pytrees matching the param tree, so they shard with the same
partition specs (moments inherit the param's spec in
repro.launch.sharding_rules).  AdamW keeps f32 moments; SGD-momentum keeps a
bf16 moment (chosen for the 314B config -- see configs/grok1_314b.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
Tree = Any


class AdamWState(NamedTuple):
    step: Array
    m: Tree
    v: Tree


class SGDMState(NamedTuple):
    step: Array
    momentum: Tree


class SGDState(NamedTuple):
    step: Array


OptState = AdamWState | SGDMState | SGDState


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    momentum: float = 0.9
    grad_clip: float = 1.0
    warmup_steps: int = 100


def adamw_init(params: Tree) -> AdamWState:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree_util.tree_map(zeros32, params),
                      jax.tree_util.tree_map(zeros32, params))


def sgdm_init(params: Tree) -> SGDMState:
    return SGDMState(jnp.zeros((), jnp.int32),
                     jax.tree_util.tree_map(
                         lambda p: jnp.zeros(p.shape, p.dtype), params))


def sgd_init(params: Tree) -> SGDState:
    return SGDState(jnp.zeros((), jnp.int32))


def global_norm(tree: Tree) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def _clip(grads: Tree, max_norm: float) -> Tree:
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (g + 1e-9))
    return jax.tree_util.tree_map(
        lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), grads)


def _schedule(cfg: OptimizerConfig, step: Array) -> Array:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    return cfg.lr * warm


def adamw_update(cfg: OptimizerConfig, grads: Tree, state: AdamWState,
                 params: Tree) -> tuple[Tree, AdamWState]:
    grads = _clip(grads, cfg.grad_clip)
    step = state.step + 1
    lr = _schedule(cfg, state.step)
    b1, b2 = cfg.beta1, cfg.beta2

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mh = m2 / (1 - b1 ** step)
        vh = v2 / (1 - b2 ** step)
        delta = lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                      + cfg.weight_decay * p.astype(jnp.float32))
        return (-delta).astype(p.dtype), m2, v2

    flat = jax.tree_util.tree_map(upd, grads, state.m, state.v, params)
    updates = jax.tree_util.tree_map(lambda t: t[0], flat,
                                     is_leaf=lambda t: isinstance(t, tuple))
    m_new = jax.tree_util.tree_map(lambda t: t[1], flat,
                                   is_leaf=lambda t: isinstance(t, tuple))
    v_new = jax.tree_util.tree_map(lambda t: t[2], flat,
                                   is_leaf=lambda t: isinstance(t, tuple))
    return updates, AdamWState(step, m_new, v_new)


def sgdm_update(cfg: OptimizerConfig, grads: Tree, state: SGDMState,
                params: Tree) -> tuple[Tree, SGDMState]:
    grads = _clip(grads, cfg.grad_clip)
    lr = _schedule(cfg, state.step)

    def upd(g, mom):
        m2 = (cfg.momentum * mom.astype(jnp.float32)
              + g.astype(jnp.float32)).astype(mom.dtype)
        return (-lr * m2.astype(jnp.float32)).astype(g.dtype), m2
    flat = jax.tree_util.tree_map(upd, grads, state.momentum)
    updates = jax.tree_util.tree_map(lambda t: t[0], flat,
                                     is_leaf=lambda t: isinstance(t, tuple))
    mom = jax.tree_util.tree_map(lambda t: t[1], flat,
                                 is_leaf=lambda t: isinstance(t, tuple))
    return updates, SGDMState(state.step + 1, mom)


def sgd_update(cfg: OptimizerConfig, grads: Tree, state: SGDState,
               params: Tree) -> tuple[Tree, SGDState]:
    lr = _schedule(cfg, state.step)
    updates = jax.tree_util.tree_map(
        lambda g: (-lr * g.astype(jnp.float32)).astype(g.dtype), grads)
    return updates, SGDState(state.step + 1)


def apply_updates(params: Tree, updates: Tree) -> Tree:
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32)
                      + u.astype(jnp.float32)).astype(p.dtype),
        params, updates)


def get_optimizer(name: str, cfg: OptimizerConfig | None = None):
    """Returns (init_fn, update_fn) for 'adamw' | 'sgdm' | 'sgd'."""
    cfg = cfg or OptimizerConfig(name=name)
    if name == "adamw":
        return adamw_init, lambda g, s, p: adamw_update(cfg, g, s, p)
    if name == "sgdm":
        return sgdm_init, lambda g, s, p: sgdm_update(cfg, g, s, p)
    if name == "sgd":
        return sgd_init, lambda g, s, p: sgd_update(cfg, g, s, p)
    raise ValueError(name)
