"""Theorem 1 / Corollary 1: tabulate the theoretical bound vs T, H, gamma --
the paper's convergence-guarantee section as a runnable artifact."""
from __future__ import annotations

import time

from repro.core import ProblemConstants, corollary1_rate, theorem1_bound
from .common import emit


def run(emit_csv: bool = True) -> dict:
    base = ProblemConstants(mu=0.5, l_smooth=4.0, g2=25.0, sigma2=4.0,
                            b=64, m=3, gamma=0.05, h=4, w0_dist2=10.0)
    out = {}
    t0 = time.time()
    for t_rounds in (500, 2000, 8000):
        out[f"T{t_rounds}"] = {
            "theorem1": theorem1_bound(base, t_rounds),
            "corollary1": corollary1_rate(base, t_rounds)}
    import dataclasses
    for h in (2, 8, 16):
        c = dataclasses.replace(base, h=h)
        out[f"H{h}"] = {"theorem1": theorem1_bound(c, 2000)}
    dt = (time.time() - t0) * 1e6 / 6
    if emit_csv:
        emit("convergence_bound", dt,
             ";".join(f"{k}={v['theorem1']:.3g}" for k, v in out.items()
                      if "theorem1" in v))
    return out


if __name__ == "__main__":
    run()
