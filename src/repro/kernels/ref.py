"""Pure-jnp oracles for every Pallas kernel in this package.

Two families of semantics:

* ``*_exact``  -- rank-exact Top_k semantics (the paper's definition), used
  to bound the approximation error of the histogram path.
* ``hist_*``   -- histogram-threshold semantics.  The Pallas kernels must
  match these *bit-exactly* (same bins, same edges); tests assert allclose
  with zero/epsilon tolerance against these.

The histogram method is the TPU-native adaptation of Top_k: a 2-pass
max-abs + 256-bin magnitude histogram replaces the global sort (bit-exact
kernel-vs-oracle agreement pinned by tests/test_kernels.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array
N_BINS = 256


# ---------------------------------------------------------------------------
# histogram threshold selection
# ---------------------------------------------------------------------------

def hist_maxabs(x: Array) -> Array:
    return jnp.max(jnp.abs(x)).astype(jnp.float32)


def hist_counts(x: Array, maxabs: Array) -> Array:
    """256-bin histogram of |x| over [0, maxabs]; bin 255 holds the largest."""
    a = jnp.abs(x).astype(jnp.float32)
    scale = jnp.where(maxabs > 0, N_BINS / maxabs, 0.0)
    bins = jnp.clip((a * scale).astype(jnp.int32), 0, N_BINS - 1)
    return jnp.zeros((N_BINS,), jnp.int32).at[bins].add(1)


def hist_thresholds(counts: Array, maxabs: Array, cum_ks: Array) -> Array:
    """Per-layer-boundary magnitude thresholds from a histogram.

    cum_ks: (C,) int32 cumulative budgets K_c = k_1 + ... + k_c.
    Returns thr: (C,) f32 where #{|x| > thr[c]} >= K_c and the overshoot is
    bounded by the mass of one bin.  thr[c] is a bin lower edge.
    """
    # count of elements in bins >= b, for each bin b  (descending cumulative)
    desc = jnp.cumsum(counts[::-1])[::-1]          # desc[b] = #{bin >= b}
    bin_w = maxabs / N_BINS

    def one(k):
        # smallest bin index b such that desc[b] >= k -> keep |x| > edge(b)
        ok = desc >= k
        b = jnp.where(jnp.any(ok), jnp.max(jnp.where(
            ok, jnp.arange(N_BINS), -1)), 0)
        return (b.astype(jnp.float32)) * bin_w
    return jax.vmap(one)(cum_ks).astype(jnp.float32)


# ---------------------------------------------------------------------------
# layered sparsify + fused error feedback (histogram semantics)
# ---------------------------------------------------------------------------

def hist_layered_sparsify(u: Array, thr: Array, received: Array) -> tuple[Array, Array]:
    """g = sum of received layers, e_new = u - g.

    Layer c keeps thr[c-1] >= |u| > thr[c] with thr[-1] := +inf.
    thr: (C,) descending bin-edge thresholds; received: (C,) bool/int.
    """
    a = jnp.abs(u)
    hi = jnp.concatenate([jnp.array([jnp.inf], jnp.float32), thr[:-1]])
    g = jnp.zeros_like(u)
    for c in range(thr.shape[0]):
        mask = (a <= hi[c]) & (a > thr[c])
        g = g + jnp.where(mask & (received[c] > 0), u, 0.0)
    return g, u - g


def hist_lgc_compress(e: Array, delta: Array, cum_ks: Array,
                      received: Array) -> tuple[Array, Array]:
    """Full histogram-LGC pipeline on flat vectors (the fused-kernel oracle).

    u = e + delta; thresholds from histogram of |u|; g = received layers;
    e_new = u - g.
    """
    u = (e + delta).astype(jnp.float32)
    m = hist_maxabs(u)
    counts = hist_counts(u, m)
    thr = hist_thresholds(counts, m, cum_ks)
    return hist_layered_sparsify(u, thr, received)


# ---------------------------------------------------------------------------
# exact oracle (for approximation-quality bounds, not kernel equality)
# ---------------------------------------------------------------------------

def exact_lgc_compress(e: Array, delta: Array, cum_ks: Array,
                       received: Array) -> tuple[Array, Array]:
    from repro.core.compressor import lgc_layers
    u = (e + delta).astype(jnp.float32)
    ks = jnp.diff(jnp.concatenate([jnp.zeros((1,), cum_ks.dtype), cum_ks]))
    layers = lgc_layers(u, [int(k) for k in ks])
    g = sum(jnp.where(received[c] > 0, layers[c], 0.0)
            for c in range(len(layers)))
    return g, u - g


# ---------------------------------------------------------------------------
# sliding-window attention oracle (decode: 1 query vs window cache)
# ---------------------------------------------------------------------------

def swa_decode_ref(q: Array, k: Array, v: Array, length: Array | None = None
                   ) -> Array:
    """q: (B,H,Dh); k,v: (B,H,W,Dh); optional valid length per batch (B,).

    Numerically-stable softmax attention of the single new token over the
    window cache.  Oracle for kernels/swa_attention.py.
    """
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(q.dtype)
    logits = jnp.einsum("bhd,bhwd->bhw", q, k) * scale
    if length is not None:
        w = k.shape[2]
        mask = jnp.arange(w)[None, None, :] < length[:, None, None]
        logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(v.dtype)
    return jnp.einsum("bhw,bhwd->bhd", p, v)
