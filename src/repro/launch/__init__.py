"""Distributed runtime: meshes, input shapes, step functions, dry-run."""
from .mesh import fl_axis_name, make_host_mesh, make_production_mesh

__all__ = ["fl_axis_name", "make_host_mesh", "make_production_mesh"]
