"""Beyond-paper extensions: QSGD quantization (cited baseline), LGC+QSGD
composition, non-IID partitions, and the bucketed selection quality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core import FLConfig, run_baseline
from repro.core.compressor import qsgd_dequantize, qsgd_quantize
from repro.models.paper_models import make_mnist_task


class TestQSGD:
    def test_roundtrip_bounded_error(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
        q, s = qsgd_quantize(x, jax.random.PRNGKey(1))
        back = qsgd_dequantize(q, s)
        # max error <= one quantization step
        step = float(s) / 127
        assert float(jnp.max(jnp.abs(back - x))) <= step + 1e-6

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 1000))
    def test_unbiased(self, seed):
        """E[dequant(quant(x))] == x -- average over rounding draws."""
        x = jax.random.normal(jax.random.PRNGKey(seed), (64,))
        keys = jax.random.split(jax.random.PRNGKey(seed + 1), 300)
        outs = jnp.stack([qsgd_dequantize(*qsgd_quantize(x, k))
                          for k in keys])
        mean = outs.mean(0)
        step = float(jnp.max(jnp.abs(x))) / 127
        np.testing.assert_allclose(np.asarray(mean), np.asarray(x),
                                   atol=step)

    def test_codes_fit_int8(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (512,)) * 100
        q, _ = qsgd_quantize(x, jax.random.PRNGKey(4))
        assert int(q.min()) >= -127 and int(q.max()) <= 127


class TestLGCQ8:
    def test_converges_with_less_uplink(self):
        task = make_mnist_task("lr", m_devices=3, n_train=1200)
        cfg = FLConfig(rounds=60, eval_every=30)
        h_q8 = run_baseline(task, cfg, "lgc_q8", h=4)
        h_lgc = run_baseline(task, cfg, "lgc", h=4)
        assert h_q8.loss[-1] < h_q8.loss[0] - 0.15      # learns
        assert h_q8.loss[-1] < h_lgc.loss[-1] + 0.3     # comparable
        # int8 values: (1+4)/(4+4) of the LGC bytes
        assert h_q8.uplink_mb[-1] < 0.8 * h_lgc.uplink_mb[-1]


class TestNonIID:
    def test_lgc_on_label_skew(self):
        from repro.data.mnist import load_synthetic_mnist, partition_noniid
        from repro.core.fl import FLTask
        from repro.models.paper_models import (_acc, _xent, lr_init,
                                               lr_logits)
        (xtr, ytr), (xte, yte) = load_synthetic_mnist(3000, 600)
        shards = partition_noniid(xtr, ytr, 3, classes_per_device=4)
        task = FLTask(
            lr_init,
            lambda p, b: _xent(lr_logits(p, b[0]), b[1]),
            lambda p, b: _acc(lr_logits(p, b[0]), b[1]),
            shards, (xte, yte), name="lr-noniid")
        # label skew slows convergence and keeps the global loss high
        # (conflicting client updates) while accuracy still climbs --
        # assert on accuracy, and that the loss does not diverge.
        cfg = FLConfig(rounds=150, eval_every=75)
        h = run_baseline(task, cfg, "lgc", h=4)
        assert h.accuracy[-1] > 0.3       # well above 10% chance
        assert h.accuracy[-1] > h.accuracy[0] + 0.15
        assert h.loss[-1] < h.loss[0] + 0.05


class TestBucketSelectionQuality:
    def test_bucket_argmax_captures_heavy_tail(self):
        """Per-bucket argmax must capture >=60% of exact top-K mass for a
        heavy-tailed vector (the I-C6 quality argument)."""
        rng = np.random.default_rng(0)
        d, k = 8192, 256
        x = rng.standard_t(df=2, size=d).astype(np.float32)  # heavy tail
        bucket = d // k
        xb = x[: k * bucket].reshape(k, bucket)
        picked = xb[np.arange(k), np.argmax(np.abs(xb), -1)]
        mass_bucket = np.sum(picked ** 2)
        topk = np.sort(np.abs(x))[-k:]
        mass_topk = np.sum(topk ** 2)
        assert mass_bucket >= 0.6 * mass_topk
