"""Checkpointing: msgpack + raw-numpy serialization of param/opt pytrees."""
from .io import latest_step, load_checkpoint, restore, save_checkpoint

__all__ = ["latest_step", "load_checkpoint", "restore", "save_checkpoint"]
