"""The 100M-parameter federated transformer task (`qwen2_100m`).

This wires the dormant big-model stack -- ``configs.qwen2_100m``, the
shard_map LGC train step in :mod:`repro.launch.steps`, the Pallas
layered-sparsify / maxabs-histogram kernels, and the synthetic token
pipeline -- into the same ``TASKS`` registry surface as the MNIST /
Shakespeare zoo: ``make_task("qwen2_100m", m_devices, scenario=...)``.

Unlike the FLTask workloads (which the loop/batched/sharded *engines*
stack into (M, d) trees -- infeasible at 1.28e8 parameters), this task IS
the sharded engine: one mesh with a data-parallel FL axis x a tensor-model
axis, ``make_lgc_train_step`` exchanging the layered channels as real
collectives, and the stacked (n_fl, .) error-feedback tree sharded over
the FL axis.  The equivalence rungs that apply at this scale are
documented in docs/ARCHITECTURE.md §12; tests/test_lgc_step.py enforces
them (sparse/bucket uplinks vs the dense server sum, mesh {1, 8}, static
and gilbert_flaky).

The scenario drives the paper's multi-channel availability: per round a
(m_devices, C) delivery mask is sampled from the scenario's
Gilbert-Elliott chains (channel c of device m up/down) plus the whole-
uplink dropout rule, and fed to the step's ``received`` argument --
undelivered mass stays in the device's error memory.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.configs.base import ArchConfig
from repro.core.scenario import (Scenario, dropout_mask, get_scenario,
                                 init_carry, step_carry)
from repro.data.tokens import TokenPipeline
from repro.launch import compat
from repro.launch import sharding_rules as rules
from repro.launch.mesh import fl_axis_name, make_host_mesh
from repro.launch.steps import (LGCStepConfig, init_ef_tree,
                                lgc_wire_bytes_per_round,
                                make_lgc_train_step)
from repro.models import transformer as tf

Array = jax.Array


@dataclasses.dataclass
class LGCTransformerTask:
    """A registry task backed by the shard_map LGC train step.

    ``build()`` constructs the mesh/params/step once; ``run(steps)``
    drives training and returns the loss trajectory plus wire accounting.
    """
    arch: ArchConfig
    m_devices: int
    scenario: Scenario
    step_cfg: LGCStepConfig
    batch_per_device: int = 2
    seq: int = 64
    seed: int = 0
    model_axis: int = 1
    name: str = "qwen2-100m"

    _built: dict | None = dataclasses.field(default=None, repr=False)

    @property
    def n_devices(self) -> int:
        return self.m_devices * self.model_axis

    def param_count(self) -> int:
        p = jax.eval_shape(lambda k: tf.init_params(self.arch, k),
                           jax.ShapeDtypeStruct((2,), jnp.uint32))
        return sum(int(x.size) for x in jax.tree_util.tree_leaves(p))

    def wire_bytes_per_round(self) -> int:
        """Per-device uplink bytes under the configured aggregate mode."""
        p = jax.eval_shape(lambda k: tf.init_params(self.arch, k),
                           jax.ShapeDtypeStruct((2,), jnp.uint32))
        return lgc_wire_bytes_per_round(p, self.step_cfg)[
            self.step_cfg.aggregate]

    # -- construction -------------------------------------------------------

    def build(self) -> dict:
        if self._built is not None:
            return self._built
        cfg = self.arch
        mesh = make_host_mesh(self.n_devices, model=self.model_axis)
        compat.set_mesh(mesh)
        fl_ax = fl_axis_name(mesh)
        params = tf.init_params(cfg, jax.random.PRNGKey(self.seed))
        pipe = TokenPipeline(cfg.vocab_size, self.seq,
                             self.batch_per_device * self.m_devices,
                             seed=self.seed)
        x0, y0 = pipe.next_batch()
        batch0 = {"tokens": jnp.asarray(x0), "labels": jnp.asarray(y0)}
        bspecs = rules.batch_specs(cfg, batch0, mesh)
        pspecs = rules.param_specs(cfg, params, mesh)
        especs = rules.ef_specs(pspecs, fl_ax)
        params = rules.place(params, pspecs, mesh)
        ef = rules.place(init_ef_tree(params, self.m_devices,
                                      jnp.dtype(self.step_cfg.ef_dtype)),
                         especs, mesh)
        step = jax.jit(
            make_lgc_train_step(cfg, mesh, self.step_cfg, bspecs,
                                param_spec_tree=pspecs),
            in_shardings=compat.shardings(
                mesh, (pspecs, especs, bspecs,
                       jax.sharding.PartitionSpec(fl_ax))),
            donate_argnums=(0, 1))
        self._built = dict(mesh=mesh, fl_ax=fl_ax, params=params, ef=ef,
                           step=step, pipe=pipe, pspecs=pspecs,
                           especs=especs, bspecs=bspecs)
        return self._built

    # -- scenario-driven channel availability -------------------------------

    def _mask_state(self):
        base = jax.random.PRNGKey(self.seed)
        dev_ids = jnp.arange(self.m_devices)
        n_ch = self.step_cfg.n_channels
        carry = jax.vmap(lambda i: init_carry(self.scenario, base, i, n_ch)
                         )(dev_ids)
        return base, dev_ids, carry

    def _round_mask(self, base, dev_ids, carry, t: int):
        """Advance the per-device chains and realise the (m, C) delivery
        mask for sync round ``t`` -- Gilbert-Elliott channel availability
        AND whole-uplink dropout, both keyed on the shared TAG streams so
        any engine observing the same scenario agrees."""
        tt = jnp.int32(t)
        carry = jax.vmap(lambda c, i: step_carry(
            self.scenario, base, c, tt, i, jnp.bool_(True)))(carry, dev_ids)
        up = carry.good.astype(jnp.int32)                    # (m, C)
        drop = dropout_mask(self.scenario, base, tt, dev_ids)  # (m,)
        received = up * (~drop).astype(jnp.int32)[:, None]
        return carry, received

    # -- training -----------------------------------------------------------

    def run(self, steps: int, log_every: int = 0) -> dict:
        """Train for ``steps`` sync rounds; returns losses + throughput +
        wire accounting (the bench consumes this directly)."""
        b = self.build()
        params, ef, step, pipe = b["params"], b["ef"], b["step"], b["pipe"]
        base, dev_ids, carry = self._mask_state()
        losses, t_steady = [], None
        t0 = time.perf_counter()
        for i in range(steps):
            carry, received = self._round_mask(base, dev_ids, carry, i)
            x, y = pipe.next_batch()
            params, ef, loss = step(params, ef,
                                    {"tokens": jnp.asarray(x),
                                     "labels": jnp.asarray(y)}, received)
            losses.append(float(loss))   # float() syncs the step
            if i == 0:
                t_steady = time.perf_counter()   # exclude compile
            if log_every and (i % log_every == 0 or i == steps - 1):
                print(f"[{self.name}] round {i:4d} loss {losses[-1]:.4f} "
                      f"({time.perf_counter() - t0:.0f}s)")
        steady_s = (time.perf_counter() - t_steady) if steps > 1 else 0.0
        # device-steps/s: every sync round advances each of the m devices
        # by H local steps
        dev_steps = (steps - 1) * self.m_devices * self.step_cfg.local_steps
        self._built["params"], self._built["ef"] = params, ef
        return {
            "losses": losses,
            "device_steps_per_s": (dev_steps / steady_s) if steady_s else 0.0,
            "wire_bytes_per_round_per_device": self.wire_bytes_per_round(),
            "param_count": self.param_count(),
        }


def make_qwen2_100m_task(m_devices: int = 8, seed: int = 0,
                         scenario: str | Scenario | None = None,
                         preset: str = "full",
                         sparsity: tuple = (0.01, 0.02, 0.02),
                         aggregate: str = "sparse_gather",
                         local_steps: int = 2, local_lr: float = 3e-3,
                         batch_per_device: int = 2, seq: int = 64,
                         backend: str = "pallas",
                         pallas_min_elems: int | None = None,
                         model_axis: int = 1,
                         arch: ArchConfig | None = None
                         ) -> LGCTransformerTask:
    """Factory behind ``make_task("qwen2_100m", ...)``.

    ``preset="full"`` is the real ~128M-parameter config (1.28e8-element
    flattened gradients -- every matmul leaf above ``PALLAS_MIN_ELEMS``);
    ``preset="smoke"`` is the tiny same-shape variant for tests and CI.
    ``backend="pallas"`` routes the dense-path compression of the big
    leaves through the fused Pallas pipeline (interpret mode on CPU).
    """
    if arch is None:
        arch = (get_config("qwen2-100m") if preset == "full"
                else get_smoke_config("qwen2-100m"))
    scn = get_scenario(scenario)
    kw = {} if pallas_min_elems is None else {
        "pallas_min_elems": pallas_min_elems}
    step_cfg = LGCStepConfig(local_steps=local_steps, local_lr=local_lr,
                             sparsity=tuple(sparsity), aggregate=aggregate,
                             backend=backend, **kw)
    return LGCTransformerTask(arch=arch, m_devices=m_devices, scenario=scn,
                              step_cfg=step_cfg, seed=seed,
                              batch_per_device=batch_per_device, seq=seq,
                              model_axis=model_axis, name=arch.name)
