"""StarCoder2-7B [arXiv:2402.19173] -- dense GQA kv=4, RoPE, layernorm,
non-gated GELU MLP, attention bias."""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b", arch_type="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
    d_ff=18_432, vocab_size=49_152,
    qkv_bias=True, mlp="gelu", norm="layernorm",
    fsdp=True,
    source="arXiv:2402.19173",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="starcoder2-smoke", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=2, d_ff=512, vocab_size=512, fsdp=False, remat=False,
        attn_q_chunk=64)
