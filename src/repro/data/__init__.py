"""Data pipelines: synthetic MNIST, embedded Shakespeare, LM token streams,
and federated partitioners (IID, label-subset, Dirichlet, quantity skew)."""
from .mnist import load_synthetic_mnist, partition_iid, partition_noniid
from .partition import (label_marginals, partition_dirichlet,
                        partition_quantity_skew, skew_score)
from .shakespeare import CHAR_VOCAB, char_batches, load_shakespeare
from .tokens import TokenPipeline, synthetic_token_batch

__all__ = [
    "load_synthetic_mnist", "partition_iid", "partition_noniid",
    "label_marginals", "partition_dirichlet", "partition_quantity_skew",
    "skew_score",
    "CHAR_VOCAB", "char_batches", "load_shakespeare",
    "TokenPipeline", "synthetic_token_batch",
]
