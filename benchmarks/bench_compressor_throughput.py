"""Compression-kernel throughput: Pallas (interpret) vs pure-jnp oracle vs
exact rank-based Top_k, across gradient sizes.  On real TPU hardware the
pallas_call path is the deployed one; interpret mode numbers here are
correctness-weighted, not perf claims (noted in EXPERIMENTS.md)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.compressor import top_k
from repro.kernels import lgc_compress_hist, lgc_compress_hist_ref
from .common import emit, time_call


def run(sizes=(65_536, 1_048_576), emit_csv: bool = True) -> dict:
    out = {}
    for d in sizes:
        key = jax.random.PRNGKey(d)
        e = jnp.zeros((d,), jnp.float32)
        delta = jax.random.normal(key, (d,))
        cum_ks = jnp.array([d // 100, d // 100 + d // 50], jnp.int32)
        recv = jnp.ones((2,), jnp.int32)

        us_ref = time_call(lgc_compress_hist_ref, e, delta, cum_ks, recv,
                           iters=3)
        us_pallas = time_call(
            lambda *a: lgc_compress_hist(*a), e, delta, cum_ks, recv, iters=3)
        us_exact = time_call(
            jax.jit(lambda x: top_k(x, d // 50 + d // 100)), delta, iters=3)
        out[d] = {"hist_ref_us": us_ref, "hist_pallas_interp_us": us_pallas,
                  "exact_topk_us": us_exact}
        if emit_csv:
            emit(f"compressor_hist_ref_d{d}", us_ref,
                 f"exact_topk_us={us_exact:.0f}")
            emit(f"compressor_pallas_interp_d{d}", us_pallas, "")
    return out


if __name__ == "__main__":
    run()
