"""Controller overhead per sync boundary: FleetDDPG vs per-agent loop.

The paper's control plane makes every device act / observe / train at each
synchronization.  The legacy path is M per-device agents behind the
ControllerFleet shim -- M host round-trips (act dispatch + replay insert +
train step + select) per boundary.  FleetDDPG stacks the M agents into
(M, .) pytrees and serves the whole boundary with one jitted call per
stage.  Both are driven through an identical synthetic spend trajectory
(training engaged), timed over steady-state boundaries, and checked for
bit-identical decisions.

Writes ``BENCH_controller.json`` (rows per M + the decision-equivalence
flag) via benchmarks.run; standalone: --out/--ms/--events.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import ControllerFleet
from repro.core.controller import DDPGConfig, DDPGController, FleetDDPG

from .common import emit

STATE_STEP = np.array([10.0, 0.01, 1.0, 0.5])
K_TOTAL = 4000
BATCH = 8


def _controllers(kind: str, m: int, seed: int = 0):
    cfg = lambda s: DDPGConfig(k_total_max=K_TOTAL, batch_size=BATCH, seed=s)
    if kind == "fleet":
        return FleetDDPG(m, cfg(seed))
    return ControllerFleet(
        [DDPGController(cfg(seed + 17 * i)) for i in range(m)])


def _drive(fleet, m: int, warmup: int, iters: int, seed: int = 0):
    """Run act+observe boundaries on a synthetic spend trajectory; returns
    (us_per_sync, decision trace)."""
    rng = np.random.RandomState(seed)
    state = np.zeros((m, 4))
    decisions = []

    def boundary():
        nonlocal state
        h, ks = fleet.act(state.astype(np.float32))
        decisions.append((tuple(int(x) for x in h),
                          tuple(tuple(int(k) for k in row) for row in ks)))
        state = state + rng.rand(m, 4) * STATE_STEP
        fleet.observe(rng.randn(m) * 0.05, state.astype(np.float32))
        return h

    for _ in range(warmup):
        boundary()
    t0 = time.perf_counter()
    for _ in range(iters):
        boundary()
    dt = time.perf_counter() - t0
    return dt / iters * 1e6, decisions


def run(ms=(8, 64), warmup: int = 10, iters: int = 10,
        emit_csv: bool = True) -> dict:
    rows = []
    match = True
    for m in ms:
        us_list, dec_list = _drive(_controllers("list", m), m, warmup, iters)
        us_fleet, dec_fleet = _drive(_controllers("fleet", m), m, warmup,
                                     iters)
        match &= dec_list == dec_fleet
        speedup = us_list / us_fleet
        rows.append({"m": int(m), "per_agent_us_per_sync": us_list,
                     "fleet_us_per_sync": us_fleet, "speedup": speedup})
        if emit_csv:
            emit(f"controller_scaling_m{m}", us_fleet,
                 f"per_agent_us={us_list:.0f};speedup={speedup:.1f}x;"
                 f"decisions_match={dec_list == dec_fleet}")
    return {"rows": rows, "decisions_match": bool(match),
            "batch_size": BATCH, "warmup": warmup, "iters": iters}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ms", type=int, nargs="+", default=[8, 64])
    ap.add_argument("--events", type=int, default=10,
                    help="timed boundaries per config")
    ap.add_argument("--out", default="BENCH_controller.json")
    args = ap.parse_args()
    res = run(ms=tuple(args.ms), iters=args.events)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
