"""Unit + property tests for the LGC compressor and error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.core import (EFState, LGCCompressor, ef_compress, flatten_tree,
                        lgc_compress, lgc_layers, top_alpha_beta, top_k,
                        tree_size, unflatten_like, wire_bytes)


def _vec(n, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n,))


class TestTopK:
    def test_keeps_k_largest(self):
        x = jnp.array([0.1, -5.0, 3.0, 0.01, -2.0])
        out = top_k(x, 2)
        np.testing.assert_allclose(out, [0.0, -5.0, 3.0, 0.0, 0.0])

    def test_k_zero_and_full(self):
        x = _vec(32)
        assert jnp.all(top_k(x, 0) == 0)
        np.testing.assert_allclose(top_k(x, 32), x)
        np.testing.assert_allclose(top_k(x, 100), x)

    def test_nnz_exact(self):
        x = _vec(257, seed=3)
        for k in (1, 17, 256):
            assert int((top_k(x, k) != 0).sum()) == k


class TestTopAlphaBeta:
    def test_band_selection(self):
        # |x| ranks: 5 > 4 > 3 > 2 > 1
        x = jnp.array([1.0, -2.0, 3.0, -4.0, 5.0])
        out = top_alpha_beta(x, 1, 3)  # ranks 1,2 (0-based) -> |4|,|3|
        np.testing.assert_allclose(out, [0.0, 0.0, 3.0, -4.0, 0.0])

    def test_complement_of_topk(self):
        x = _vec(100, seed=1)
        np.testing.assert_allclose(top_alpha_beta(x, 0, 10), top_k(x, 10))


class TestLGCLayers:
    def test_layers_disjoint_and_sum_to_topk(self):
        x = _vec(500, seed=2)
        ks = [25, 50, 100]
        layers = lgc_layers(x, ks)
        nnz_union = sum((l != 0).astype(jnp.int32) for l in layers)
        assert int(nnz_union.max()) == 1  # disjoint support
        np.testing.assert_allclose(sum(layers), top_k(x, sum(ks)), rtol=0, atol=0)

    def test_layer_sizes(self):
        x = _vec(300, seed=4)
        ks = [10, 20, 40]
        for l, k in zip(lgc_layers(x, ks), ks):
            assert int((l != 0).sum()) == k

    def test_base_layer_has_largest_magnitudes(self):
        x = _vec(200, seed=5)
        base, enh = lgc_layers(x, [20, 20])
        base_min = jnp.abs(base[base != 0]).min()
        enh_max = jnp.abs(enh[enh != 0]).max()
        assert float(base_min) >= float(enh_max)

    def test_channel_dropout_partial_sum(self):
        x = _vec(100, seed=6)
        ks = [10, 10, 10]
        got = lgc_compress(x, ks, received=[True, False, True])
        layers = lgc_layers(x, ks)
        np.testing.assert_allclose(got, layers[0] + layers[2])


class TestErrorFeedback:
    def test_identity_u_eq_g_plus_e(self):
        x = _vec(400, seed=7)
        comp = LGCCompressor([20, 30])
        g, st = ef_compress(EFState(jnp.zeros(400)), x, comp)
        np.testing.assert_array_equal(np.asarray(g + st.e), np.asarray(x))

    def test_memory_accumulates_then_drains(self):
        """A coordinate too small to send eventually leaves via the memory."""
        comp = LGCCompressor([1])
        d = 8
        st = EFState(jnp.zeros(d))
        delta = jnp.full((d,), 0.1).at[0].set(1.0)
        sent_mass = jnp.zeros(d)
        for _ in range(12):
            g, st = ef_compress(st, delta, comp)
            sent_mass = sent_mass + g
        # after enough rounds every coordinate has been transmitted at least once
        assert int((sent_mass != 0).sum()) > 1

    def test_dropped_layer_mass_retained(self):
        x = _vec(100, seed=8)
        comp = LGCCompressor([10, 10])
        g, st = ef_compress(EFState(jnp.zeros(100)), x, comp,
                            received=[True, False])
        # enhancement-layer mass must sit in the error memory
        layers = comp.layers(x)
        np.testing.assert_allclose(np.asarray(st.e[layers[1] != 0]),
                                   np.asarray(x[layers[1] != 0]), rtol=1e-6)


class TestPytreeFlatten:
    def test_roundtrip(self):
        tree = {"a": jnp.ones((3, 4)), "b": {"c": jnp.arange(5.0)}}
        flat = flatten_tree(tree)
        assert flat.shape == (17,)
        back = unflatten_like(flat, tree)
        for l1, l2 in zip(jax.tree_util.tree_leaves(tree),
                          jax.tree_util.tree_leaves(back)):
            np.testing.assert_allclose(l1, l2)

    def test_tree_size(self):
        assert tree_size({"a": jnp.ones((3, 4)), "b": jnp.ones(5)}) == 17


class TestWireBytes:
    def test_values_plus_indices(self):
        assert wire_bytes([10, 20]) == [80, 160]
        assert wire_bytes([10], value_bytes=2, index_bytes=4) == [60]


class TestTracedSelection:
    """The batched engine's traced-budget selections must reproduce the
    rank-exact oracle bit-for-bit (same stable tie-breaking)."""

    def _all(self, x, ks, received, k_cap):
        from repro.core import lgc_compress_topk, lgc_compress_traced
        ks_a = jnp.asarray(ks, jnp.int32)
        rc_a = jnp.asarray(received)
        oracle = lgc_compress(x, ks, received=received)
        traced = lgc_compress_traced(x, ks_a, rc_a)
        topk = jax.jit(lgc_compress_topk, static_argnums=3)(
            x, ks_a, rc_a, k_cap)
        np.testing.assert_array_equal(np.asarray(traced), np.asarray(oracle))
        np.testing.assert_array_equal(np.asarray(topk), np.asarray(oracle))

    def test_matches_oracle(self):
        for seed in range(4):
            self._all(_vec(300, seed), [10, 20, 40],
                      [True, False, True], 128)

    def test_zero_and_full_budgets(self):
        x = _vec(64, 9)
        self._all(x, [0, 8, 0], [True, True, True], 16)
        self._all(x, [32, 32, 32], [True, True, False], 64)

    def test_ties_split_by_index_order(self):
        # duplicated magnitudes straddling a layer boundary
        x = jnp.array([1.0, -1.0, 1.0, 0.5, -1.0, 2.0, 1.0, 0.25])
        self._all(x, [2, 3], [True, True], 8)
        self._all(x, [3, 2], [True, False], 4)

    def test_vmapped_equals_sequential(self):
        from repro.core import lgc_compress_topk
        xs = jnp.stack([_vec(200, s) for s in range(6)])
        ks = jnp.tile(jnp.array([[15, 25, 10]], jnp.int32), (6, 1))
        rc = jnp.ones((6, 3), bool)
        batched = jax.vmap(
            lambda u, k, r: lgc_compress_topk(u, k, r, 64))(xs, ks, rc)
        for i in range(6):
            one = lgc_compress(xs[i], [15, 25, 10])
            np.testing.assert_array_equal(np.asarray(batched[i]),
                                          np.asarray(one))


class TestPerLayer:
    """Per-model-layer budget path (repro.core.compressor per-layer section).

    Contract: layer candidate masks are disjoint (disjoint slices), budgets
    sum to k_total ("uniform" always, "size_prop" whenever k_total <= D),
    and the "uniform" policy is BIT-equal to the global top-k path -- the
    property that lets FLConfig.layer_policy ride the equivalence ladder.
    """

    def _tree(self):
        return {"a": jnp.zeros((40, 5)), "b": jnp.zeros((64,)),
                "c": {"w": jnp.zeros((12, 8))}}

    def _setup(self, seed=0):
        from repro.core.compressor import tree_layer_slices
        slices = tree_layer_slices(self._tree())
        d = slices[-1][2]
        return slices, d, _vec(d, seed)

    def test_slices_cover_flat_vector(self):
        from repro.core.compressor import tree_layer_slices
        tree = self._tree()
        slices = tree_layer_slices(tree)
        assert slices[0][1] == 0 and slices[-1][2] == tree_size(tree)
        for (_, _, hi), (_, lo2, _) in zip(slices, slices[1:]):
            assert hi == lo2                      # contiguous, no gaps
        # skip_leading_axes drops the stacked device axis
        stacked = jax.tree_util.tree_map(
            lambda a: jnp.zeros((7,) + a.shape), tree)
        assert tree_layer_slices(stacked, skip_leading_axes=1) == slices

    def test_budgets_sum_and_bounds(self):
        from repro.core.compressor import LAYER_POLICIES, layer_budgets
        slices, d, u = self._setup()
        sizes = [hi - lo for _, lo, hi in slices]
        for k_total in (1, 37, 150, d):
            for pol in ("uniform", "size_prop"):
                b = np.asarray(layer_budgets(pol, u, slices,
                                             jnp.int32(k_total), d))
                assert b.sum() == k_total, (pol, k_total, b)
                assert (b >= 0).all() and (b <= sizes).all()
        assert set(LAYER_POLICIES) == {"uniform", "size_prop", "divergence"}

    def test_divergence_budget_follows_mass(self):
        from repro.core.compressor import layer_budgets
        slices, d, _ = self._setup()
        # all update mass in layer "b" -> it gets (almost) all the budget
        u = jnp.zeros((d,)).at[slices[1][1]:slices[1][2]].set(5.0)
        b = np.asarray(layer_budgets("divergence", u, slices,
                                     jnp.int32(30), d))
        assert b[1] == 30 and b[0] == 0 and b[2] == 0

    def test_candidate_masks_disjoint_and_sized(self):
        from repro.core.compressor import layer_budgets, per_layer_candidates
        slices, d, u = self._setup(seed=3)
        for pol in ("uniform", "size_prop", "divergence"):
            b = layer_budgets(pol, u, slices, jnp.int32(90), d)
            mask = per_layer_candidates(u, slices, b, d)
            for i, (_, lo, hi) in enumerate(slices):
                assert int(mask[lo:hi].sum()) == int(b[i])

    def test_uniform_bit_equals_global(self):
        from repro.core.compressor import (lgc_compress_topk,
                                           per_layer_compress)
        slices, d, _ = self._setup()
        ks = jnp.asarray([20, 30, 40], jnp.int32)
        recv = jnp.asarray([True, False, True])
        for seed in range(4):
            u = _vec(d, seed)
            np.testing.assert_array_equal(
                np.asarray(per_layer_compress(u, ks, recv, slices,
                                              "uniform", d)),
                np.asarray(lgc_compress_topk(u, ks, recv, d)))

    def test_uniform_bit_equals_global_under_ties(self):
        from repro.core.compressor import (lgc_compress_topk,
                                           per_layer_compress)
        slices, d, u = self._setup(seed=7)
        # integer-valued magnitudes: massive tie groups across layers
        u = jnp.round(u * 2.0)
        ks = jnp.asarray([15, 25], jnp.int32)
        recv = jnp.asarray([True, True])
        np.testing.assert_array_equal(
            np.asarray(per_layer_compress(u, ks, recv, slices,
                                          "uniform", d)),
            np.asarray(lgc_compress_topk(u, ks, recv, d)))

    def test_nonuniform_sends_same_coordinate_count(self):
        from repro.core.compressor import per_layer_compress
        slices, d, u = self._setup(seed=5)
        ks = jnp.asarray([30, 30], jnp.int32)
        recv = jnp.asarray([True, True])
        for pol in ("size_prop", "divergence"):
            g = per_layer_compress(u, ks, recv, slices, pol, d)
            assert int((g != 0).sum()) == 60

    def test_per_layer_wire_bytes_smaller_indices(self):
        from repro.core.compressor import per_layer_wire_bytes, wire_bytes
        slices, d, _ = self._setup()
        budgets = [20, 30, 40]
        per_layer = per_layer_wire_bytes(budgets, slices)
        # every layer here is < 2^8 coordinates -> 1-byte local indices
        assert per_layer == sum(b * (4 + 1) for b in budgets)
        assert per_layer < sum(wire_bytes(budgets))

    def test_hist_routing_threshold_is_invisible(self):
        from repro.core.compressor import (layer_budgets,
                                           per_layer_candidates_hist)
        slices, d, u = self._setup(seed=2)
        b = layer_budgets("size_prop", u, slices, jnp.int32(80), d)
        via_ref = per_layer_candidates_hist(u, slices, b,
                                            pallas_min_elems=10 ** 9)
        via_pallas = per_layer_candidates_hist(u, slices, b,
                                               pallas_min_elems=1)
        np.testing.assert_array_equal(np.asarray(via_pallas),
                                      np.asarray(via_ref))


# ---------------------------------------------------------------------------
# property-based tests (hypothesis)
# ---------------------------------------------------------------------------

@st.composite
def vec_and_ks(draw):
    n = draw(st.integers(8, 512))
    seed = draw(st.integers(0, 2 ** 16))
    c = draw(st.integers(1, 4))
    ks = [draw(st.integers(0, max(1, n // (c + 1)))) for _ in range(c)]
    return n, seed, ks


@settings(max_examples=40, deadline=None)
@given(vec_and_ks())
def test_prop_lgc_equals_topk_union(args):
    n, seed, ks = args
    x = _vec(n, seed)
    np.testing.assert_allclose(np.asarray(lgc_compress(x, ks)),
                               np.asarray(top_k(x, sum(ks))))


@settings(max_examples=40, deadline=None)
@given(vec_and_ks())
def test_prop_contraction(args):
    """Compressor contraction: ||u - C(u)||^2 <= (1 - K/D) ||u||^2."""
    n, seed, ks = args
    x = _vec(n, seed)
    resid = x - lgc_compress(x, ks)
    k = min(sum(ks), n)
    lhs = float(jnp.sum(resid ** 2))
    rhs = (1 - k / n) * float(jnp.sum(x ** 2))
    assert lhs <= rhs + 1e-5


@settings(max_examples=40, deadline=None)
@given(vec_and_ks())
def test_prop_error_feedback_conservation(args):
    n, seed, ks = args
    x = _vec(n, seed)
    comp = LGCCompressor(ks)
    g, st = ef_compress(EFState(jnp.zeros(n)), x, comp)
    np.testing.assert_allclose(np.asarray(g + st.e), np.asarray(x),
                               rtol=0, atol=0)


@settings(max_examples=30, deadline=None)
@given(vec_and_ks(), st.booleans())
def test_prop_per_layer_uniform_equals_global(args, quantize):
    """uniform per-layer policy == global top-k, bitwise, ties included."""
    from repro.core.compressor import lgc_compress_topk, per_layer_compress
    n, seed, ks = args
    x = _vec(n, seed)
    if quantize:                     # integer magnitudes: huge tie groups
        x = jnp.round(x * 2.0)
    slices = [("a", 0, n // 3), ("b", n // 3, (2 * n) // 3),
              ("c", (2 * n) // 3, n)]
    ks_a = jnp.asarray(ks, jnp.int32)
    recv = jnp.asarray([s % 2 == 0 for s in range(len(ks))])
    np.testing.assert_array_equal(
        np.asarray(per_layer_compress(x, ks_a, recv, slices, "uniform", n)),
        np.asarray(lgc_compress_topk(x, ks_a, recv, n)))


@settings(max_examples=25, deadline=None)
@given(st.integers(8, 256), st.integers(0, 100))
def test_prop_topk_magnitude_dominance(n, seed):
    """Every kept coordinate is >= every discarded coordinate in |.|."""
    x = _vec(n, seed)
    k = max(1, n // 4)
    out = top_k(x, k)
    kept = jnp.abs(x)[out != 0]
    drop = jnp.abs(x)[out == 0]
    if drop.size and kept.size:
        assert float(kept.min()) >= float(drop.max()) - 1e-7
