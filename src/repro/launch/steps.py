"""Step functions: sync train, LGC train (the paper's technique), prefill,
serve -- all pjit/shard_map-ready.

The LGC step is the paper's Algorithm 1 mapped onto the mesh (DESIGN.md §3):
the FL-device axis is the slow axis ("pod" on the multi-pod mesh, "data" on
the single-pod mesh).  ``jax.shard_map`` is *manual* over that axis only --
inside, each FL device runs H local SGD steps on its own microbatches,
compresses its net progress with histogram-LGC + error feedback (per-tensor,
preserving every tensor's sharding over the auto axes), and the layers are
exchanged explicitly:

  * aggregate="dense_masked":  psum of the masked dense gradient -- the
    functional equivalent of the paper's server sum (full wire bytes).
  * aggregate="sparse_gather": per layer c an all_gather of fixed-k
    (values, indices) + scatter-add -- the layered multi-channel
    transmission, cutting collective bytes by ~D/(2 sum k_c).
  * aggregate="none":          FedAvg baseline (dense delta, no compression).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.compressor import PALLAS_MIN_ELEMS
from repro.kernels import lgc_compress_hist
from repro.kernels import ref as kref
from repro.models import transformer as tf
from repro.optim.optimizers import (OptimizerConfig, apply_updates,
                                    get_optimizer)
from . import compat
from .mesh import fl_axis_name

Array = jax.Array

# per-arch gradient-accumulation defaults for train_4k on the 256-chip pod
# (keeps the scan-carry activation stash under ~8 GB/chip; DESIGN.md §5)
ACCUM_STEPS = {
    "glm4-9b": 4, "yi-34b": 8, "grok-1-314b": 8, "starcoder2-7b": 4,
    "phi-3-vision-4.2b": 4, "olmoe-1b-7b": 2, "qwen2-1.5b": 2,
    "mamba2-370m": 2, "zamba2-1.2b": 2, "whisper-small": 2,
}


@dataclasses.dataclass(frozen=True)
class LGCStepConfig:
    local_steps: int = 4                   # H: local SGD steps per sync
    local_lr: float = 1e-3
    sparsity: tuple = (0.01, 0.02, 0.02)   # per-channel k_c / D fractions
    # dense_masked | sparse_gather | bucket_sparse | none
    aggregate: str = "dense_masked"
    ef_dtype: str = "float32"
    # I-C7: exchange the masked update in bf16 (EF keeps the f32 residual,
    # including the rounding error -- error feedback absorbs quantisation
    # exactly like sparsification).  Halves cross-pod bytes for the
    # dense_masked mode on TPU.  Default f32 because XLA:CPU's
    # AllReducePromotion pass aborts on bf16 all-reduce ("Invalid binary
    # instruction opcode copy") -- flip to "bfloat16" on real TPU.
    psum_dtype: str = "float32"
    # "pallas" routes dense-path leaves of >= pallas_min_elems elements
    # through the fused kernels.lgc_compress_hist pipeline (bit-identical
    # to the kref oracle -- tests/test_kernels.py); smaller leaves stay on
    # the oracle either way.  "exact" keeps everything on the oracle.
    # pallas_interpret=True is the CPU parity mode; flip off on real TPU.
    backend: str = "exact"
    pallas_min_elems: int = PALLAS_MIN_ELEMS
    pallas_interpret: bool = True

    @property
    def n_channels(self) -> int:
        return len(self.sparsity)


# ---------------------------------------------------------------------------
# sync (standard data+tensor-parallel) training -- the framework baseline
# ---------------------------------------------------------------------------

def make_sync_train_step(cfg: ArchConfig, *, accum_steps: int = 1,
                         opt_cfg: OptimizerConfig | None = None):
    _, opt_update = get_optimizer(cfg.optimizer, opt_cfg)

    def loss_fn(p, mb):
        return tf.lm_loss(p, cfg, mb)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                                    *x.shape[1:]), batch)

            def acc(carry, mb):
                loss_sum, g_sum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g_sum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_sum, g)
                return (loss_sum + l, g_sum), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc, (jnp.float32(0.0), g0), mbs)
            loss = loss / accum_steps
            grads = jax.tree_util.tree_map(
                lambda g, p: (g / accum_steps).astype(p.dtype), grads, params)
        updates, opt_state = opt_update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, loss

    return train_step


# ---------------------------------------------------------------------------
# LGC training step (Algorithm 1 on the mesh)
# ---------------------------------------------------------------------------

def _leaf_ks(size: int, sparsity: Sequence[float]) -> list[int]:
    """Per-channel k budgets, cumulatively clamped to the leaf size.

    The naive ``max(1, int(size * f))`` floor lets the *cumulative* budget
    exceed the leaf for small leaves (a 64-element bias at sparsity
    (0.01, 0.02, 0.02) requests 3 coords; a 2-element leaf requests 3):
    the overflow channels then get degenerate (zero) thresholds and their
    bands either truncate or double-cover coordinates.  Clamping the
    cumulative sum keeps the channels disjoint by construction: channel c
    owns ranks [cum[c-1], cum[c]) and trailing channels degrade to k=0
    (empty band, no collective payload) once the leaf is exhausted.
    Pinned by tests/test_lgc_step.py::TestSmallLeafBudgets.
    """
    ks = [max(1, int(size * f)) for f in sparsity]
    cum = np.minimum(np.cumsum(ks), size)
    return np.diff(np.concatenate([[0], cum])).tolist()


def _leaf_cum_ks(size: int, sparsity: Sequence[float]) -> jnp.ndarray:
    return jnp.asarray(np.cumsum(_leaf_ks(size, sparsity)), jnp.int32)


def _compress_leaf_dense(e: Array, delta: Array, sparsity, recv: Array,
                         *, backend: str = "exact",
                         pallas_min_elems: int = PALLAS_MIN_ELEMS,
                         interpret: bool = True) -> tuple[Array, Array]:
    """Histogram-LGC on one tensor; returns (g, e_new) with leaf's shape.

    ``recv`` is this FL device's (C,) per-channel delivery mask: masked
    channels contribute nothing to the wire sum and their mass stays in
    the error memory.  Leaves of >= ``pallas_min_elems`` elements route
    through the fused Pallas pipeline when ``backend == "pallas"`` -- at
    qwen2_100m scale that is every matmul leaf (ARCHITECTURE.md §12).
    """
    shape = delta.shape
    e_flat = e.reshape(-1).astype(jnp.float32)
    d_flat = delta.reshape(-1).astype(jnp.float32)
    cum_ks = _leaf_cum_ks(d_flat.shape[0], sparsity)
    if backend == "pallas" and d_flat.shape[0] >= pallas_min_elems:
        g, e_new = lgc_compress_hist(e_flat, d_flat, cum_ks, recv,
                                     interpret=interpret)
    else:
        g, e_new = kref.hist_lgc_compress(e_flat, d_flat, cum_ks, recv)
    return g.reshape(shape), e_new.reshape(shape)


def _model_axis_of(spec) -> int | None:
    """Index of the dimension a PartitionSpec shards over 'model'."""
    if spec is None:
        return None
    for i, ax in enumerate(spec):
        if ax == "model" or (isinstance(ax, tuple) and "model" in ax):
            return i
    return None


def _compress_leaf_sparse(e: Array, delta: Array, sparsity, recv: Array,
                          fl_ax: str, n_fl: int, spec=None
                          ) -> tuple[Array, Array]:
    """Layered sparse exchange: per channel, all_gather fixed-k (val, idx).

    Each LGC layer is an independent collective -- the multi-channel
    transmission.  Returns (g_mean_global, e_new_local).

    SHARD-ALIGNED selection (perf iterations I-C2/I-C3, EXPERIMENTS.md
    §Perf): a global top-k over a model-sharded leaf forces GSPMD to
    all-gather the whole tensor (measured: cross-pod bytes UP 4x -- the
    original hypothesis refuted), and a naive (rows, cols) reshape is not
    shard-aligned either (involuntary-full-remat warnings, no improvement).
    The fix moves the leaf's OWN model-sharded axis to the front, so the
    (rows, cols) view is a local relabeling; every shard then selects its
    own k/rows coordinates, the pod-axis all_gather moves only sharded
    (val, idx) pairs, and the rank bias of shard-local selection is
    absorbed by the error-feedback memory.
    """
    from repro.models.layers import maybe_constrain
    shape = delta.shape
    u0 = e + delta.astype(jnp.float32)
    ax = _model_axis_of(spec) if delta.ndim else None
    if ax is not None:
        u = jnp.moveaxis(u0, ax, 0).reshape(shape[ax], -1)
        u = maybe_constrain(u, "model", None)
    else:
        u = u0.reshape(1, -1)
    rows, cols = u.shape

    # per-row magnitude histogram -> per-row layer thresholds (all local)
    mx = jax.vmap(kref.hist_maxabs)(u)                     # (rows,)
    counts = jax.vmap(kref.hist_counts)(u, mx)             # (rows, 256)
    ks = _leaf_ks(cols, sparsity)            # cumulative clamp: see _leaf_ks
    cum = jnp.asarray(np.cumsum(ks), jnp.int32)
    thr = jax.vmap(lambda c, m: kref.hist_thresholds(c, m, cum)
                   )(counts, mx)                           # (rows, C)
    a = jnp.abs(u)
    hi = jnp.concatenate([jnp.full((rows, 1), jnp.inf), thr[:, :-1]], 1)

    g_own = jnp.zeros_like(u)
    g_sum = jnp.zeros_like(u)
    for c, k_c in enumerate(ks):
        if k_c == 0:
            # channel budget exhausted by the clamp: empty band on every
            # device (ks is host-side, so all shards skip the collective)
            continue
        band = jnp.where((a <= hi[:, c:c + 1]) & (a > thr[:, c:c + 1]), a, 0.0)
        k_eff = min(k_c + max(1, cols // kref.N_BINS), cols)
        bvals, idx = jax.lax.top_k(band, k_eff)            # (rows, k_eff)
        # bvals==0 slots are top_k ties on empty band positions: masking
        # their values dedupes the (arbitrary) repeated indices, and the
        # recv mask drops undelivered channels (their mass stays in EF)
        vals = (jnp.take_along_axis(u, idx, 1) * (bvals > 0)
                * recv[c].astype(jnp.float32))
        if ax is not None:
            vals = maybe_constrain(vals, "model", None)
            idx = maybe_constrain(idx, "model", None)
        g_own = jax.vmap(lambda g, i, v: g.at[i].add(v))(g_own, idx, vals)
        # ---- one collective per LGC layer (the "channel") ----
        # (I-C5: re-pin the gathered buffers to the model axis -- the
        # all_gather result otherwise materialises replicated per chip,
        # which is what kept xpod at the unsharded size in I-C4)
        vals_all = jax.lax.all_gather(vals, fl_ax)         # (n_fl, rows, k)
        idx_all = jax.lax.all_gather(idx, fl_ax)
        if ax is not None:
            vals_all = maybe_constrain(vals_all, None, "model", None)
            idx_all = maybe_constrain(idx_all, None, "model", None)
        for fl in range(n_fl):
            g_sum = jax.vmap(lambda g, i, v: g.at[i].add(v)
                             )(g_sum, idx_all[fl], vals_all[fl])
    e_new = u - g_own
    g_mean = g_sum / n_fl
    if ax is not None:
        back = lambda t: jnp.moveaxis(
            t.reshape((shape[ax],) + shape[:ax] + shape[ax + 1:]), 0, ax)
        return back(g_mean), back(e_new)
    return g_mean.reshape(shape), e_new.reshape(shape)


def _compress_leaf_bucket(e: Array, delta: Array, sparsity, recv: Array,
                          fl_ax: str, n_fl: int, spec=None
                          ) -> tuple[Array, Array]:
    """Bucketed layered selection (perf iteration I-C6, beyond-paper).

    ``lax.top_k`` lowers to a sort, and XLA's sort partitioning replicates a
    model-sharded operand (measured: the sparse exchange stayed at the
    unsharded byte count through I-C4/C5).  Bucket-argmax sidesteps sort
    entirely: split each shard-local row into K strided buckets and keep
    each bucket's max-|.| element -- a pure reduction that partitions
    cleanly.  Selection is a randomized top-K approximation (bucket maxima
    ~ top-K for heavy-tailed gradients); the un-sent mass stays in the
    error-feedback memory exactly as for exact top-K, so Lemma 1 applies
    with a (slightly smaller) per-shard gamma.  Channel c owns k_c of the
    K buckets -- the layers stay disjoint by construction.
    """
    from repro.models.layers import maybe_constrain
    shape = delta.shape
    u0 = e + delta.astype(jnp.float32)
    ax = _model_axis_of(spec) if delta.ndim else None
    if ax is not None:
        u = jnp.moveaxis(u0, ax, 0).reshape(shape[ax], -1)
        u = maybe_constrain(u, "model", None)
    else:
        u = u0.reshape(1, -1)
    rows, cols = u.shape
    ks = _leaf_ks(cols, sparsity)            # cumulative clamp: see _leaf_ks
    k_total = sum(ks)
    bucket = max(cols // k_total, 1)
    k_eff = cols // bucket
    used = k_eff * bucket
    ub = u[:, :used].reshape(rows, k_eff, bucket)
    pos_in = jnp.argmax(jnp.abs(ub), -1)                   # (rows, k_eff)
    vals = jnp.take_along_axis(ub, pos_in[..., None], -1)[..., 0]
    idx = (jnp.arange(k_eff)[None, :] * bucket + pos_in).astype(jnp.int32)
    if ax is not None:
        vals = maybe_constrain(vals, "model", None)
        idx = maybe_constrain(idx, "model", None)

    # one all_gather per channel-layer: channel c carries buckets
    # [sum(ks[:c]), sum(ks[:c+1])) -- disjoint layers, separate collectives.
    # g_own accumulates ONLY the delivered slices: buckets past the channel
    # budget (k_eff > k_total) or on a masked channel are never transmitted,
    # so their mass must stay in the error memory (the seed code credited
    # every bucket to g_own, silently leaking the untransmitted tail).
    g_own = jnp.zeros_like(u)
    g_sum = jnp.zeros_like(u)
    lo = 0
    for c, k_c in enumerate(ks):
        hi = min(lo + k_c, k_eff)
        if hi <= lo:
            break
        v_c = vals[:, lo:hi] * recv[c].astype(jnp.float32)
        i_c = idx[:, lo:hi]
        g_own = jax.vmap(lambda g, i, v: g.at[i].add(v))(g_own, i_c, v_c)
        v_all = jax.lax.all_gather(v_c, fl_ax)             # (n_fl, rows, k_c)
        i_all = jax.lax.all_gather(i_c, fl_ax)
        for fl in range(n_fl):
            g_sum = jax.vmap(lambda g, i, v: g.at[i].add(v)
                             )(g_sum, i_all[fl], v_all[fl])
        lo = hi
    e_new = u - g_own
    g_mean = g_sum / n_fl
    if ax is not None:
        back = lambda t: jnp.moveaxis(
            t.reshape((shape[ax],) + shape[:ax] + shape[ax + 1:]), 0, ax)
        return back(g_mean), back(e_new)
    return g_mean.reshape(shape), e_new.reshape(shape)


def make_lgc_train_step(cfg: ArchConfig, mesh, step_cfg: LGCStepConfig,
                        batch_spec_tree, param_spec_tree=None):
    """Algorithm 1: returns f(params, ef, batch, received=None)
    -> (params, ef, metrics).

    Server update is plain subtraction (Alg. 1 line 21); the optimizer lives
    on the devices as plain SGD (line 6), exactly as in the paper.
    ``param_spec_tree`` (optional) enables shard-aligned sparse selection
    in the sparse_gather mode (see _compress_leaf_sparse).

    The error-feedback tree uses the stacked ``(n_fl, *leaf)`` convention
    (:func:`init_ef_tree`), sharded ``P(fl_ax)``: each FL device owns its
    own residual row.  The seed code kept per-device EF under a replicated
    ``P()`` spec -- undefined with ``check_rep=False``, and ``device_get``
    (and therefore every checkpoint) silently collapsed it to shard 0's
    residual (tests/test_checkpoint.py pins the round-trip).

    ``received`` (optional, (n_fl, C) int) is the per-device per-channel
    delivery mask for the sync round -- the multi-channel availability the
    paper's scenarios drive (gilbert_flaky etc.).  Masked channels are
    never transmitted; their mass stays in the device's error memory (the
    same dropout+EF rule the engines use).  ``None`` means all delivered.
    The FedAvg baseline (aggregate="none") has no channels and ignores it.
    """
    fl_ax = fl_axis_name(mesh)
    n_fl = dict(zip(mesh.axis_names, mesh.devices.shape))[fl_ax]
    h = step_cfg.local_steps
    n_ch = step_cfg.n_channels

    def loss_fn(p, mb):
        return tf.lm_loss(p, cfg, mb)

    # manual specs: slice only the FL axis; auto axes flow through
    def manual_batch_spec(spec):
        # keep the leading-axis entry only if it names the fl axis
        lead = spec[0] if len(spec) else None
        has_fl = lead == fl_ax or (isinstance(lead, tuple) and fl_ax in lead)
        return P(fl_ax) if has_fl else P()

    batch_in_specs = jax.tree_util.tree_map(
        manual_batch_spec, batch_spec_tree,
        is_leaf=lambda x: isinstance(x, P))

    dense_kw = dict(backend=step_cfg.backend,
                    pallas_min_elems=step_cfg.pallas_min_elems,
                    interpret=step_cfg.pallas_interpret)

    def step(params, ef, batch, received=None):
        if received is None:
            received = jnp.ones((n_fl, n_ch), jnp.int32)

        @functools.partial(
            compat.shard_map, mesh=mesh,
            in_specs=(P(), P(fl_ax), batch_in_specs, P(fl_ax)),
            out_specs=(P(), P(fl_ax), P()),
            axis_names={fl_ax})
        def inner(params, ef_stack, batch, received):
            ef = jax.tree_util.tree_map(lambda x: x[0], ef_stack)
            recv = received[0].astype(jnp.int32)      # (C,) own channels
            # ---- H local SGD steps (Alg. 1 line 6) -----------------------
            b_local = jax.tree_util.tree_leaves(batch)[0].shape[0]
            assert b_local % h == 0 and b_local >= h, (
                f"per-FL-device batch {b_local} must be divisible by "
                f"local_steps H={h}")
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape(h, x.shape[0] // h, *x.shape[1:]), batch)

            def local_sgd(carry, mb):
                p, loss_sum = carry
                l, g = jax.value_and_grad(loss_fn)(p, mb)
                p = jax.tree_util.tree_map(
                    lambda w, gi: (w.astype(jnp.float32)
                                   - step_cfg.local_lr
                                   * gi.astype(jnp.float32)).astype(w.dtype),
                    p, g)
                return (p, loss_sum + l), None

            (p_end, loss_sum), _ = jax.lax.scan(
                local_sgd, (params, jnp.float32(0.0)), mbs)
            loss = jax.lax.pmean(loss_sum / h, fl_ax)

            # ---- net progress + error feedback + LGC (lines 8-11) -------
            delta = jax.tree_util.tree_map(
                lambda w0, w1: (w0.astype(jnp.float32)
                                - w1.astype(jnp.float32)), params, p_end)

            if step_cfg.aggregate == "none":          # FedAvg baseline
                g_mean = jax.tree_util.tree_map(
                    lambda dl: jax.lax.pmean(dl, fl_ax), delta)
                ef_new = ef
            elif step_cfg.aggregate == "bucket_sparse":
                if param_spec_tree is not None:
                    pairs = jax.tree_util.tree_map(
                        lambda e, dl, sp: _compress_leaf_bucket(
                            e, dl, step_cfg.sparsity, recv, fl_ax, n_fl, sp),
                        ef, delta, param_spec_tree)
                else:
                    pairs = jax.tree_util.tree_map(
                        lambda e, dl: _compress_leaf_bucket(
                            e, dl, step_cfg.sparsity, recv, fl_ax, n_fl),
                        ef, delta)
                g_mean = jax.tree_util.tree_map(
                    lambda t: t[0], pairs,
                    is_leaf=lambda t: isinstance(t, tuple))
                ef_new = jax.tree_util.tree_map(
                    lambda t: t[1], pairs,
                    is_leaf=lambda t: isinstance(t, tuple))
            elif step_cfg.aggregate == "sparse_gather":
                if param_spec_tree is not None:
                    pairs = jax.tree_util.tree_map(
                        lambda e, dl, sp: _compress_leaf_sparse(
                            e, dl, step_cfg.sparsity, recv, fl_ax, n_fl, sp),
                        ef, delta, param_spec_tree)
                else:
                    pairs = jax.tree_util.tree_map(
                        lambda e, dl: _compress_leaf_sparse(
                            e, dl, step_cfg.sparsity, recv, fl_ax, n_fl),
                        ef, delta)
                g_mean = jax.tree_util.tree_map(
                    lambda t: t[0], pairs,
                    is_leaf=lambda t: isinstance(t, tuple))
                ef_new = jax.tree_util.tree_map(
                    lambda t: t[1], pairs,
                    is_leaf=lambda t: isinstance(t, tuple))
            else:                                      # dense_masked
                pairs = jax.tree_util.tree_map(
                    lambda e, dl: _compress_leaf_dense(
                        e, dl, step_cfg.sparsity, recv, **dense_kw),
                    ef, delta)
                g = jax.tree_util.tree_map(
                    lambda t: t[0], pairs,
                    is_leaf=lambda t: isinstance(t, tuple))
                ef_new = jax.tree_util.tree_map(
                    lambda t: t[1], pairs,
                    is_leaf=lambda t: isinstance(t, tuple))
                wire_dt = jnp.dtype(step_cfg.psum_dtype)
                g_wire = jax.tree_util.tree_map(
                    lambda gl: gl.astype(wire_dt), g)
                # quantisation residue joins the error memory (I-C7)
                ef_new = jax.tree_util.tree_map(
                    lambda en, gl, gw: en + (gl - gw.astype(jnp.float32)),
                    ef_new, g, g_wire)
                g_mean = jax.tree_util.tree_map(
                    lambda gw: jax.lax.pmean(gw, fl_ax).astype(jnp.float32),
                    g_wire)

            # ---- server update + broadcast (lines 20-21, 12) -------------
            params_new = jax.tree_util.tree_map(
                lambda w, gm: (w.astype(jnp.float32) - gm).astype(w.dtype),
                params, g_mean)
            ef_new = jax.tree_util.tree_map(
                lambda x: x.astype(jnp.dtype(step_cfg.ef_dtype))[None],
                ef_new)
            return params_new, ef_new, loss

        return inner(params, ef, batch, received)

    return step


def init_ef_tree(params, n_fl: int = 1, dtype=jnp.float32):
    """Stacked per-FL-device error-feedback tree: leaves are
    ``(n_fl, *param_shape)`` -- row m is device m's residual (the same
    stacked (M, .) convention the batched engines use).  Shard the leading
    axis ``P(fl_axis)`` via :func:`repro.launch.sharding_rules.ef_specs`.
    """
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros((n_fl,) + p.shape, dtype), params)


def lgc_wire_bytes_per_round(params, step_cfg: LGCStepConfig,
                             value_bytes: int = 4, index_bytes: int = 4
                             ) -> dict[str, int]:
    """Per-device uplink bytes for one sync round, by aggregate mode.

    Uses the clamped per-leaf channel budgets (:func:`_leaf_ks`), so small
    leaves never over-report.  ``dense_masked`` moves the full dense tensor
    through the psum (the masking saves nothing on the wire -- that is the
    point of the sparse/bucket modes); ``none`` is the FedAvg baseline.
    """
    leaves = [int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params)]
    k_total = sum(sum(_leaf_ks(n, step_cfg.sparsity)) for n in leaves)
    d_total = sum(leaves)
    psum_bytes = jnp.dtype(step_cfg.psum_dtype).itemsize
    return {
        "none": d_total * value_bytes,
        "dense_masked": d_total * psum_bytes,
        "sparse_gather": k_total * (value_bytes + index_bytes),
        "bucket_sparse": k_total * (value_bytes + index_bytes),
    }


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ArchConfig, cache_len: int | None = None):
    def prefill_step(params, batch):
        return tf.prefill(params, cfg, batch, cache_len)
    return prefill_step


def make_serve_step(cfg: ArchConfig, window: int = 0):
    def serve_step(params, token, cache):
        logits, cache = tf.decode_step(params, cfg, token, cache,
                                       window=window)
        next_token = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return next_token, cache
    return serve_step
