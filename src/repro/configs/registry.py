"""Architecture registry: ``--arch <id>`` -> ArchConfig."""
from __future__ import annotations

import importlib

from .base import ArchConfig

_MODULES = {
    "glm4-9b": "glm4_9b",
    "whisper-small": "whisper_small",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "yi-34b": "yi_34b",
    "mamba2-370m": "mamba2_370m",
    "phi-3-vision-4.2b": "phi3_vision_4b",
    "qwen2-1.5b": "qwen2_1_5b",
    "qwen2-100m": "qwen2_100m",
    "grok-1-314b": "grok1_314b",
    "zamba2-1.2b": "zamba2_1_2b",
    "starcoder2-7b": "starcoder2_7b",
}

ARCH_IDS = tuple(_MODULES)


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str) -> ArchConfig:
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ArchConfig:
    return _module(arch_id).smoke()


def list_archs() -> list[str]:
    return list(ARCH_IDS)
