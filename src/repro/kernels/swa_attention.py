"""Pallas TPU kernel: sliding-window flash attention, decode step.

One new query token attends to a ring-buffer KV cache of window size W
(the sub-quadratic attention used by dense architectures at long_500k;
oracle agreement pinned by tests/test_kernels.py::TestSWADecode).  Per (batch, head) grid step the kernel holds the query row
and one W x Dh K/V tile in VMEM and runs an online-softmax (flash) loop
over W in chunks, so the softmax is single-pass and never materialises the
(W,) probability vector in HBM.

Constraints: W * Dh * 4 bytes * 2 (K and V) must fit VMEM -- true for the
production window (4096 x 128 ~ 4 MB).  For larger windows the grid would
gain a W dimension with output rescaling; not needed here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _swa_decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *,
                       chunk: int, window: int):
    q = q_ref[0, 0, :].astype(jnp.float32)                 # (Dh,)
    valid = len_ref[0, 0]
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))

    m = jnp.float32(-jnp.inf)                              # running max
    l = jnp.float32(0.0)                                   # running denom
    acc = jnp.zeros((q.shape[-1],), jnp.float32)           # running numer

    for c0 in range(0, window, chunk):                     # static unroll
        k_blk = k_ref[0, 0, c0:c0 + chunk, :].astype(jnp.float32)
        v_blk = v_ref[0, 0, c0:c0 + chunk, :].astype(jnp.float32)
        logits = (k_blk @ q) * scale                       # (chunk,)
        pos = c0 + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)[:, 0]
        logits = jnp.where(pos < valid, logits, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(logits))
        # guard the all-masked chunk (exp(-inf - -inf)) case
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(logits - m_safe)                       # (chunk,)
        p = jnp.where(jnp.isfinite(logits), p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * corr + jnp.sum(p)
        acc = acc * corr + p @ v_blk
        m = m_new

    out = acc / jnp.maximum(l, 1e-30)
    o_ref[0, 0, :] = out.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def swa_decode(q: jax.Array, k: jax.Array, v: jax.Array,
               length: jax.Array, *, chunk: int = 512,
               interpret: bool = True) -> jax.Array:
    """Flash decode attention over a sliding-window cache.

    Args:
      q: (B, H, Dh) new-token queries.
      k, v: (B, H, W, Dh) window cache (GQA already expanded or H == KV).
      length: (B,) valid entries per batch row.

    Returns (B, H, Dh) attention output, q.dtype.
    """
    b, h, dh = q.shape
    w = k.shape[2]
    chunk = min(chunk, w)
    assert w % chunk == 0, (w, chunk)
    kernel = functools.partial(_swa_decode_kernel, chunk=chunk, window=w)
    len2 = jnp.broadcast_to(length.reshape(b, 1), (b, 1)).astype(jnp.int32)
    return pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, w, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, w, dh), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, dh), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, dh), q.dtype),
        interpret=interpret,
    )(q, k, v, len2)
