"""Roofline terms from a compiled (dry-run) executable.

    compute    = HLO_FLOPs / peak_FLOPs          (per chip)
    memory     = HLO_bytes / HBM_bw              (per chip)
    collective = collective_bytes / link_bw      (per chip)

Sources: ``compiled.cost_analysis()`` provides per-device HLO FLOPs and
bytes (the SPMD module is the per-device program on this backend -- verified
in tests/test_roofline.py).  collective_bytes is parsed from the compiled
HLO text: we sum the RESULT-buffer bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute instruction (a consistent,
documented convention; ring-algorithm constants ~2(n-1)/n are folded into
the interpretation, not the number).

MODEL_FLOPS = 6*N*D for training (3x forward 2ND: fwd+bwd), 2*N*D for
inference, with N = active params for MoE.  The ratio MODEL_FLOPS /
(HLO_FLOPs * chips) is the "useful compute" fraction -- remat recompute and
dispatch overhead push it below 1 for training (remat ~ 4ND/6ND floor).
"""
from __future__ import annotations

import dataclasses
import re

# TPU v5e per-chip constants (assignment-specified)
@dataclasses.dataclass(frozen=True)
class _HW:
    peak_flops: float = 197e12       # bf16 FLOP/s
    hbm_bw: float = 819e9            # B/s
    ici_bw: float = 50e9             # B/s per link


HW = _HW()

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(pred|[sufc]\d+|bf16)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, int]:
    """Sum result-buffer bytes per collective kind over the module."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        lhs, _, rhs = stripped.partition("=")
        m = re.match(r"\s*(?:\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES)
                     + r")(?:-start|-done)?\(", rhs.strip())
        if not m:
            continue
        kind = m.group(1)
        if "-done(" in rhs:      # async pair: count only the -start
            continue
        head = rhs.strip().split(kind)[0]
        for dt, dims in _SHAPE_RE.findall(head):
            out[kind] += _shape_bytes(dt, dims)
    return out


def model_flops(cfg, shape_kind: str, n_tokens: int) -> float:
    """6ND (train) / 2ND (inference) with N = active params."""
    n = cfg.active_param_count()
    per_tok = 6 * n if shape_kind == "train" else 2 * n
    return float(per_tok) * n_tokens


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    mode: str
    n_chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    collective_bytes_per_chip: float
    cross_pod_bytes_per_chip: float
    collective_breakdown: dict
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops_total: float
    useful_ratio: float
    memory_args_gb: float
    memory_temp_gb: float
    memory_out_gb: float

    def asdict(self):
        return dataclasses.asdict(self)

    def summary(self) -> str:
        return (f"{self.arch:18s} {self.shape:12s} {self.mesh:9s} "
                f"{self.mode:12s} "
                f"Tc={self.t_compute * 1e3:9.3f}ms "
                f"Tm={self.t_memory * 1e3:9.3f}ms "
                f"Tcoll={self.t_collective * 1e3:9.3f}ms "
                f"xpod={self.cross_pod_bytes_per_chip / 2**30:7.2f}GB "
                f"dom={self.bottleneck:10s} useful={self.useful_ratio:6.3f} "
                f"mem={self.memory_args_gb + self.memory_temp_gb:6.1f}GB")


def analyze_compiled(compiled, cfg, *, arch: str, shape: str, shape_kind: str,
                     n_tokens: int, mesh_desc: str, mode: str,
                     n_chips: int) -> RooflineReport:
    # trip-count-aware walker (XLA's cost_analysis counts while bodies once;
    # see analysis/hlo_cost.py and tests/test_roofline.py)
    from .hlo_cost import analyze_hlo
    cost = analyze_hlo(compiled.as_text())
    flops = float(cost.flops)
    byts = float(cost.bytes)
    coll = dict(cost.coll)
    coll_total = float(sum(coll.values()))
    t_c = flops / HW.peak_flops
    t_m = byts / HW.hbm_bw
    t_x = coll_total / HW.ici_bw
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape_kind, n_tokens)
    mem = compiled.memory_analysis()
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_desc, mode=mode, n_chips=n_chips,
        hlo_flops_per_chip=flops, hlo_bytes_per_chip=byts,
        collective_bytes_per_chip=coll_total,
        cross_pod_bytes_per_chip=float(cost.cross_pod_bytes),
        collective_breakdown=coll,
        t_compute=t_c, t_memory=t_m, t_collective=t_x, bottleneck=dom,
        model_flops_total=mf,
        useful_ratio=mf / max(flops * n_chips, 1.0),
        memory_args_gb=mem.argument_size_in_bytes / 2**30,
        memory_temp_gb=mem.temp_size_in_bytes / 2**30,
        memory_out_gb=mem.output_size_in_bytes / 2**30,
    )
