"""Paper Figure 6: char-RNN on Shakespeare -- convergence + resources."""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.core import (FLConfig, LGCSimulator, make_fleet_ddpg,
                        run_baseline, tree_size)
from repro.models.paper_models import make_shakespeare_task

from .common import emit


def run(rounds: int = 60, emit_csv: bool = True) -> dict:
    task = make_shakespeare_task(m_devices=3, seq=48)
    cfg = FLConfig(rounds=rounds, eval_every=max(rounds // 6, 1),
                   batch_size=32)
    out = {}
    for mode, label in (("lgc", "lgc_fixed"), ("fedavg", "fedavg")):
        t0 = time.time()
        h = run_baseline(task, cfg, mode, h=4)
        out[label] = h.asdict()
        if emit_csv:
            emit(f"fig6_rnn_{label}", (time.time() - t0) * 1e6 / rounds,
                 f"acc={h.accuracy[-1]:.3f};loss={h.loss[-1]:.3f};"
                 f"energy_j={h.energy_j[-1]:.0f};money={h.money[-1]:.4f}")
    d = tree_size(task.init(jax.random.PRNGKey(0)))
    fleet = make_fleet_ddpg(3, d)
    t0 = time.time()
    h = LGCSimulator(task, cfg, fleet, mode="lgc").run()
    out["lgc_ddpg"] = h.asdict()
    if emit_csv:
        emit(f"fig6_rnn_lgc_ddpg", (time.time() - t0) * 1e6 / rounds,
             f"acc={h.accuracy[-1]:.3f};loss={h.loss[-1]:.3f};"
             f"energy_j={h.energy_j[-1]:.0f};money={h.money[-1]:.4f}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    res = run(rounds=args.rounds)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
