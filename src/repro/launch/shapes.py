"""Assigned input shapes and ShapeDtypeStruct stand-ins (no allocation).

  train_4k     seq_len=4,096    global_batch=256   -> train_step
  prefill_32k  seq_len=32,768   global_batch=32    -> prefill_step
  decode_32k   seq_len=32,768   global_batch=128   -> serve_step (1 token,
                                                      KV cache of seq_len)
  long_500k    seq_len=524,288  global_batch=1     -> serve_step with
               sub-quadratic state: SSM/hybrid native, dense archs via the
               sliding-window cache (cfg.window), DESIGN.md §4.

``input_specs(cfg, shape)`` returns a dict of jax.ShapeDtypeStruct matching
the step function's runtime inputs -- weak-type-correct, shardable, and
never materialised.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tf


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

VIS_STUB_DIM = 1024     # CLIP ViT-L/14 feature width (stub frontend)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def token_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """Train/prefill token inputs (+ modality prefix stubs)."""
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": _sds((b, s), jnp.int32)}
    if shape.kind == "train":
        out["labels"] = _sds((b, s), jnp.int32)
    if cfg.arch_type == "vlm":
        out["prefix"] = _sds((b, cfg.n_prefix_tokens, VIS_STUB_DIM), cfg.dtype)
    if cfg.arch_type == "audio":
        out["prefix"] = _sds((b, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    return out


def decode_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    """serve_step inputs: one new token + a cache of shape.seq_len context.

    long_500k uses the ring-buffer window cache (cfg.window) for attention
    archs -- sub-linear memory AND sub-quadratic compute; SSM state caches
    are O(1) in seq regardless.
    """
    b = shape.global_batch
    is_long = shape.seq_len > 65_536
    cache_len = min(shape.seq_len, cfg.window) if is_long else shape.seq_len
    cache = jax.eval_shape(lambda: tf.init_cache(cfg, b, cache_len))
    return {"token": _sds((b, 1), jnp.int32), "cache": cache}


def input_specs(cfg: ArchConfig, shape_name: str) -> dict:
    shape = SHAPES[shape_name]
    if shape.kind == "decode":
        return decode_specs(cfg, shape)
    return token_specs(cfg, shape)


def concrete_batch(cfg: ArchConfig, shape_name: str, key=None) -> dict:
    """Materialise a random batch matching input_specs (small shapes only)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    specs = input_specs(cfg, shape_name)

    def make(s):
        if s.dtype == jnp.int32:
            return jax.random.randint(key, s.shape, 0, max(cfg.vocab_size, 2))
        return jax.random.normal(key, s.shape, s.dtype)
    return jax.tree_util.tree_map(make, specs)


def window_for(cfg: ArchConfig, shape_name: str) -> int:
    """Window argument passed to decode_step: nonzero only for long_500k."""
    shape = SHAPES[shape_name]
    if shape.kind == "decode" and shape.seq_len > 65_536:
        return cfg.window
    return 0
