"""Minimal but real checkpointing: pytrees -> msgpack (structure) + .npy blobs.

Layout:  <dir>/step_<N>/manifest.msgpack  (treedef paths, dtypes, shapes)
         <dir>/step_<N>/arr_<i>.npy       (one blob per leaf)

Works for params, optimizer states and error-feedback states (anything
jax.tree_util can flatten with key paths).  bfloat16 leaves are stored as
uint16 views with a dtype tag (numpy has no bf16).
"""
from __future__ import annotations

import os
import re

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _leaf_to_numpy(x) -> tuple[np.ndarray, str]:
    # np.asarray AFTER device_get: python scalars (step counters in
    # training-state trees) have no .dtype and crashed the seed version
    x = np.asarray(jax.device_get(x))
    if x.dtype == jnp.bfloat16:
        return x.view(np.uint16), "bfloat16"
    return x, str(x.dtype)


def _numpy_to_leaf(arr: np.ndarray, tag: str, like_leaf=None):
    """Restore one leaf bit-exactly.

    The seed version did ``jnp.asarray(arr.astype(tag))``, which silently
    downcasts int64/float64 blobs (python-scalar leaves) when jax runs
    with x64 disabled -- not a round-trip.  Python-scalar template leaves
    are restored as python scalars; everything else must come back with
    exactly the dtype it was saved with.
    """
    if tag == "bfloat16":
        return jnp.asarray(arr.view(jnp.bfloat16))
    if str(arr.dtype) != tag:
        raise ValueError(f"checkpoint blob dtype {arr.dtype} != manifest "
                         f"tag {tag!r} (corrupt checkpoint?)")
    if isinstance(like_leaf, (int, float)) and not isinstance(
            like_leaf, (np.generic, np.ndarray)) and arr.ndim == 0:
        return type(like_leaf)(arr.item())
    out = jnp.asarray(arr)
    if str(out.dtype) != tag:
        # x64-disabled jax cannot hold this dtype; keep the numpy array
        # rather than silently truncating bits
        return arr
    return out


def save_checkpoint(directory: str, step: int, tree) -> str:
    path = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    manifest = {"n_leaves": len(leaves), "treedef": str(treedef),
                "step": step, "dtypes": [], "shapes": []}
    for i, leaf in enumerate(leaves):
        arr, tag = _leaf_to_numpy(leaf)
        manifest["dtypes"].append(tag)
        manifest["shapes"].append(list(arr.shape))
        np.save(os.path.join(path, f"arr_{i:05d}.npy"), arr)
    with open(os.path.join(path, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    return path


def load_checkpoint(directory: str, step: int, like):
    """Restore into the structure of ``like`` (a template pytree)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    assert manifest["n_leaves"] == len(leaves_like), \
        f"checkpoint has {manifest['n_leaves']} leaves, template has {len(leaves_like)}"
    if manifest.get("treedef") != str(treedef):
        raise ValueError(
            "checkpoint treedef does not match template structure "
            "(same leaf count, different tree) -- refusing to restore "
            "into the wrong pytree layout")
    leaves = []
    for i, (tag, tmpl) in enumerate(zip(manifest["dtypes"], leaves_like)):
        arr = np.load(os.path.join(path, f"arr_{i:05d}.npy"))
        want = list(np.shape(tmpl))
        if manifest["shapes"][i] != want:
            raise ValueError(
                f"leaf {i}: checkpoint shape {manifest['shapes'][i]} != "
                f"template shape {want}")
        leaves.append(_numpy_to_leaf(arr, tag, tmpl))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.match(r"step_(\d+)$", d))]
    return max(steps) if steps else None


def restore(directory: str, like):
    step = latest_step(directory)
    if step is None:
        return None, None
    return load_checkpoint(directory, step, like), step
