"""Server aggregation modes and the bounded-staleness contract (§11).

The contract this suite enforces, in rungs:

* **Identity rung (bitwise).** ``aggregator="mean"`` + ``staleness_cap=0``
  -- the defaults -- must leave every engine on its original program:
  History dict-equal to a config that never mentions the new fields.
* **Non-mean equivalence (own tolerance).** ``diloco`` / ``semi_sync``
  keep loop~batched allclose and batched==sharded bitwise (gather mode);
  psum matches to reassociation tolerance.
* **Degeneracy pins.** diloco(outer_lr=1, outer_momentum=0) == mean;
  semi_sync with an infinite deadline == mean; semi_sync with a
  vanishing deadline and cap=0 freezes the global model (every update
  returned to EF).
* **Convergence floor.** Under the scenario zoo's stress profiles, the
  async modes still learn, and semi_sync's simulated wall-clock beats
  the sync barrier under stragglers.

The unit half tests the pure jnp math in :mod:`repro.core.server`
directly on crafted arrays.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AGGREGATORS, FLConfig, ServerState, get_aggregator,
                        init_server_state, run_baseline, window_deadline)
from repro.core.scenario import Scenario, StragglerSpec
from repro.core.server import (diloco_update, semi_sync_sums,
                               semi_sync_update, staleness_schedule)
from repro.models.paper_models import make_mnist_task

N_DEV = len(jax.devices())

STRAGGLERS = Scenario(name="stragglers",
                      straggler=StragglerSpec(slow_every=4, slowdown=3.0))


@pytest.fixture(scope="module")
def task8():
    return make_mnist_task("lr", m_devices=8, n_train=1500)


@pytest.fixture(scope="module")
def task8_strag():
    return make_mnist_task("lr", m_devices=8, n_train=1500,
                           scenario=STRAGGLERS)


# ---------------------------------------------------------------------------
# registry + config validation
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_registry_contents(self):
        assert set(AGGREGATORS) == {"mean", "diloco", "semi_sync"}
        assert get_aggregator("mean").carries_state is False
        assert get_aggregator("semi_sync").uses_timing is True

    def test_unknown_aggregator_raises(self):
        with pytest.raises(ValueError, match="unknown aggregator"):
            get_aggregator("fedprox")

    def test_simulator_rejects_unknown_aggregator(self, task8):
        cfg = FLConfig(rounds=4, aggregator="nope")
        with pytest.raises(ValueError, match="unknown aggregator"):
            run_baseline(task8, cfg, "lgc")

    def test_negative_staleness_cap_raises(self):
        with pytest.raises(ValueError, match="staleness_cap"):
            init_server_state(
                FLConfig(aggregator="semi_sync", staleness_cap=-1), 8)

    def test_state_sizing(self):
        s = init_server_state(
            FLConfig(aggregator="semi_sync", staleness_cap=3), 5)
        assert s.momentum.shape == (5,) and s.stale.shape == (3, 5)
        s = init_server_state(FLConfig(aggregator="diloco"), 5)
        assert s.stale.shape == (0, 5)


# ---------------------------------------------------------------------------
# the pure server math, on crafted arrays
# ---------------------------------------------------------------------------

class TestStalenessMath:
    def test_schedule_buckets(self):
        # deadline 1.0, cap 2: T=0.5 on time, T=1.5 one window late,
        # T=2.5 two late (at cap), T=9.0 past cap -> dropped
        T = jnp.asarray([0.5, 1.5, 2.5, 9.0], jnp.float32)
        mask = jnp.asarray([True] * 4)
        s, w, on, und = staleness_schedule(T, jnp.float32(1.0), mask,
                                           alpha=0.5, cap=2)
        np.testing.assert_array_equal(np.asarray(s), [0, 1, 2, 8])
        np.testing.assert_array_equal(np.asarray(on), [True] + [False] * 3)
        np.testing.assert_allclose(np.asarray(w)[:3],
                                   [1.0, 2 ** -0.5, 3 ** -0.5], rtol=1e-6)
        # undelivered: 0 on time, 1-w while buffered, all of it past cap
        np.testing.assert_allclose(
            np.asarray(und),
            [0.0, 1 - 2 ** -0.5, 1 - 3 ** -0.5, 1.0], rtol=1e-6)

    def test_schedule_masks_out_non_syncing(self):
        T = jnp.asarray([5.0, 5.0], jnp.float32)
        mask = jnp.asarray([True, False])
        s, _, on, und = staleness_schedule(T, jnp.float32(1.0), mask,
                                           alpha=1.0, cap=1)
        assert float(und[1]) == 0.0 and float(s[1]) == 0.0 and not bool(on[1])

    def test_sums_route_to_ring_rows(self):
        # device 0 on time, 1 one late, 2 two late, 3 dropped
        g = jnp.eye(4, dtype=jnp.float32) * 10.0
        T = jnp.asarray([0.5, 1.5, 2.5, 9.0], jnp.float32)
        mask = jnp.ones(4, bool)
        g_now, contrib, n_sync = semi_sync_sums(g, T, mask, jnp.float32(1.0),
                                                alpha=0.5, cap=2)
        assert int(n_sync) == 4
        np.testing.assert_allclose(np.asarray(g_now), [10, 0, 0, 0], atol=0)
        c = np.asarray(contrib)
        np.testing.assert_allclose(c[0], [0, 10 * 2 ** -0.5, 0, 0], rtol=1e-6)
        np.testing.assert_allclose(c[1], [0, 0, 10 * 3 ** -0.5, 0], rtol=1e-6)

    def test_update_folds_maturing_row_and_shifts(self):
        state = ServerState(momentum=jnp.zeros(3),
                            stale=jnp.asarray([[3., 0, 0], [0, 5., 0]]))
        flat = jnp.zeros(3)
        g_now = jnp.asarray([1., 0, 0])
        contrib = jnp.asarray([[0., 0, 7.], [0., 0, 0]])
        new_flat, new_state = semi_sync_update(flat, state, g_now, contrib,
                                               jnp.bool_(True), m_total=2)
        # applied: (g_now + maturing row 0) / m
        np.testing.assert_allclose(np.asarray(new_flat), [-2.0, 0, 0])
        # ring shifted up one window, new deposits added
        np.testing.assert_allclose(np.asarray(new_state.stale),
                                   [[0, 5., 7.], [0, 0, 0]])

    def test_update_no_fold_is_identity(self):
        state = ServerState(momentum=jnp.zeros(3),
                            stale=jnp.asarray([[3., 0, 0], [0, 5., 0]]))
        flat = jnp.asarray([1., 2., 3.])
        new_flat, new_state = semi_sync_update(
            flat, state, jnp.ones(3), jnp.ones((2, 3)), jnp.bool_(False), 2)
        np.testing.assert_array_equal(np.asarray(new_flat), np.asarray(flat))
        np.testing.assert_array_equal(np.asarray(new_state.stale),
                                      np.asarray(state.stale))

    def test_diloco_nesterov_step(self):
        state = ServerState(momentum=jnp.asarray([2.0]), stale=jnp.zeros((0, 1)))
        flat = jnp.asarray([10.0])
        delta = jnp.asarray([1.0])
        new_flat, new_state = diloco_update(flat, state, delta,
                                            jnp.bool_(True), 0.5, 0.9)
        # m' = 0.9*2 + 1 = 2.8; step = 0.5*(1 + 0.9*2.8) = 1.76
        np.testing.assert_allclose(float(new_state.momentum[0]), 2.8)
        np.testing.assert_allclose(float(new_flat[0]), 10 - 1.76, rtol=1e-6)

    def test_window_deadline_median_and_factor(self):
        cfg = FLConfig(deadline_factor=2.0)
        from repro.core.channels import DeviceProfile
        p = DeviceProfile()
        items = [(4, [100, 50, 50], p), (4, [100, 50, 50], p),
                 (8, [100, 50, 50], p)]
        dl = window_deadline(cfg, "lgc", 7850, items)
        base = [pp.comp_time_per_step_s * h
                + max(k * 8 / 1e6 / c.bandwidth_mb_s
                      for k, c in zip(ks, cfg.channels))
                for h, ks, pp in items]
        assert dl == pytest.approx(2.0 * float(np.median(base)))


# ---------------------------------------------------------------------------
# the identity rung: defaults leave the ladder bitwise intact
# ---------------------------------------------------------------------------

class TestMeanIdentityRung:
    @pytest.mark.parametrize("engine", ["loop", "batched"])
    def test_explicit_mean_bitwise_equals_default(self, task8, engine):
        base = dict(rounds=16, eval_every=8)
        h_def = run_baseline(task8, FLConfig(**base), "lgc", engine=engine)
        h_mean = run_baseline(
            task8, FLConfig(aggregator="mean", staleness_cap=0, **base),
            "lgc", engine=engine)
        assert h_mean.asdict() == h_def.asdict()

    def test_mean_has_no_server_state(self, task8):
        from repro.core import LGCSimulator
        from repro.core.fl import FixedController
        sim = LGCSimulator(task8, FLConfig(rounds=4),
                           [FixedController(4, [200, 100, 100])
                            for _ in range(8)])
        assert sim.server_state is None and sim._server_apply is None


# ---------------------------------------------------------------------------
# non-mean equivalence: loop ~ batched == sharded at their own tolerance
# ---------------------------------------------------------------------------

def _cfg(agg, **kw):
    extra = dict(rounds=20, eval_every=10)
    if agg == "semi_sync":
        extra["staleness_cap"] = 2
    extra.update(kw)
    return FLConfig(aggregator=agg, **extra)


class TestAsyncEquivalence:
    @pytest.mark.parametrize("agg", ["diloco", "semi_sync"])
    def test_loop_matches_batched(self, task8_strag, agg):
        cfg = _cfg(agg, scenario=STRAGGLERS)
        hl = run_baseline(task8_strag, cfg, "lgc", engine="loop")
        hb = run_baseline(task8_strag, cfg, "lgc", engine="batched")
        assert hl.step == hb.step
        np.testing.assert_allclose(hb.loss, hl.loss, atol=1e-4)
        np.testing.assert_allclose(hb.accuracy, hl.accuracy, atol=1e-4)
        np.testing.assert_allclose(hb.uplink_mb, hl.uplink_mb, atol=1e-4)
        np.testing.assert_allclose(hb.server_wall_s, hl.server_wall_s,
                                   rtol=1e-6)

    @pytest.mark.skipif(N_DEV < 2, reason="single-device mesh is trivial")
    @pytest.mark.parametrize("agg", ["diloco", "semi_sync"])
    def test_sharded_gather_bitwise_matches_batched(self, task8_strag, agg):
        cfg = _cfg(agg, scenario=STRAGGLERS)
        hb = run_baseline(task8_strag, cfg, "lgc", engine="batched")
        hs = run_baseline(task8_strag, cfg, "lgc", engine="sharded",
                          server_reduce="gather")
        assert hs.asdict() == hb.asdict()

    @pytest.mark.skipif(N_DEV < 2, reason="single-device mesh is trivial")
    @pytest.mark.parametrize("agg", ["diloco", "semi_sync"])
    def test_sharded_psum_matches_batched(self, task8_strag, agg):
        cfg = _cfg(agg, scenario=STRAGGLERS)
        hb = run_baseline(task8_strag, cfg, "lgc", engine="batched")
        hs = run_baseline(task8_strag, cfg, "lgc", engine="sharded",
                          server_reduce="psum")
        np.testing.assert_allclose(hs.loss, hb.loss, atol=1e-5)
        np.testing.assert_allclose(hs.accuracy, hb.accuracy, atol=1e-5)
        # wall-clock is host f64 off the same sync sets: exactly equal
        assert hs.server_wall_s == hb.server_wall_s

    def test_sharded_mesh1_runs(self, task8):
        # the mesh-size-1 degenerate case of the sharded program
        cfg = _cfg("semi_sync")
        from repro.launch.mesh import make_host_mesh
        h = run_baseline(task8, cfg, "lgc", engine="sharded",
                         mesh=make_host_mesh(1))
        assert np.isfinite(h.loss[-1])


# ---------------------------------------------------------------------------
# degeneracy pins
# ---------------------------------------------------------------------------

class TestDegeneracy:
    def test_diloco_identity_params_reduce_to_mean(self, task8):
        base = dict(rounds=20, eval_every=10)
        hm = run_baseline(task8, FLConfig(**base), "lgc", engine="batched")
        hd = run_baseline(
            task8, FLConfig(aggregator="diloco", outer_lr=1.0,
                            outer_momentum=0.0, **base),
            "lgc", engine="batched")
        np.testing.assert_allclose(hd.loss, hm.loss, atol=1e-5)
        np.testing.assert_allclose(hd.accuracy, hm.accuracy, atol=1e-5)
        # identical sync barrier -> identical simulated wall
        assert hd.server_wall_s == hm.server_wall_s

    def test_semi_sync_generous_deadline_reduces_to_mean(self, task8_strag):
        # with the deadline far beyond any realised window time every
        # device is on-time at weight 1: exactly the synchronous mean
        base = dict(rounds=20, eval_every=10, scenario=STRAGGLERS)
        hm = run_baseline(task8_strag, FLConfig(**base), "lgc",
                          engine="batched")
        hs = run_baseline(
            task8_strag, FLConfig(aggregator="semi_sync", staleness_cap=2,
                                  deadline_factor=1e6, **base),
            "lgc", engine="batched")
        np.testing.assert_allclose(hs.loss, hm.loss, atol=1e-5)
        np.testing.assert_allclose(hs.accuracy, hm.accuracy, atol=1e-5)

    def test_semi_sync_vanishing_deadline_freezes_model(self, task8):
        # deadline ~ 0 and cap 0: every update is late past the cap, all
        # mass returns to EF, the global model never moves (the eval
        # subset is keyed per round, so loss jitters -- check params)
        from repro.core.fl import FixedController, LGCSimulator
        cfg = FLConfig(rounds=12, eval_every=4, aggregator="semi_sync",
                       staleness_cap=0, deadline_factor=1e-12)
        ctrls = [FixedController(4, [100, 50, 47]) for _ in range(8)]
        sim = LGCSimulator(task8, cfg, ctrls, mode="lgc", engine="batched")
        before = jax.tree_util.tree_map(np.array, sim.params)
        sim.run()
        after = jax.tree_util.tree_map(np.asarray, sim.params)
        for b, a in zip(jax.tree_util.tree_leaves(before),
                        jax.tree_util.tree_leaves(after)):
            np.testing.assert_array_equal(b, a)

    def test_semi_sync_static_scenario_is_on_time(self, task8):
        # homogeneous devices, static channels: nobody misses the median-
        # derived deadline, so semi_sync matches mean exactly
        base = dict(rounds=16, eval_every=8)
        hm = run_baseline(task8, FLConfig(**base), "lgc", engine="batched")
        hs = run_baseline(
            task8, FLConfig(aggregator="semi_sync", staleness_cap=2, **base),
            "lgc", engine="batched")
        np.testing.assert_allclose(hs.loss, hm.loss, atol=1e-6)


# ---------------------------------------------------------------------------
# convergence floor + the wall-clock claim
# ---------------------------------------------------------------------------

class TestConvergenceFloor:
    @pytest.mark.parametrize("agg", ["diloco", "semi_sync"])
    @pytest.mark.parametrize("scn", ["gilbert_flaky", "stragglers"])
    def test_async_modes_still_learn(self, agg, scn):
        scenario = STRAGGLERS if scn == "stragglers" else scn
        task = make_mnist_task("lr", m_devices=8, n_train=1500,
                               scenario=scenario)
        cfg = _cfg(agg, rounds=40, eval_every=20, scenario=scenario)
        h = run_baseline(task, cfg, "lgc", engine="batched")
        assert h.loss[-1] < h.loss[0] - 0.1
        assert np.isfinite(h.loss).all()

    def test_semi_sync_beats_sync_wall_under_stragglers(self, task8_strag):
        base = dict(rounds=24, eval_every=12, scenario=STRAGGLERS)
        hm = run_baseline(task8_strag, FLConfig(**base), "lgc",
                          engine="batched")
        hs = run_baseline(
            task8_strag, FLConfig(aggregator="semi_sync", staleness_cap=2,
                                  **base), "lgc", engine="batched")
        # the sync server waits for the 3x-slow stragglers every window;
        # the deadline server does not
        assert hs.server_wall_s[-1] < 0.6 * hm.server_wall_s[-1]

    def test_wall_monotone_nondecreasing(self, task8):
        h = run_baseline(task8, FLConfig(rounds=20, eval_every=5), "lgc")
        w = h.server_wall_s
        assert all(b >= a for a, b in zip(w, w[1:])) and w[-1] > 0


# ---------------------------------------------------------------------------
# population layer: the shared server step honours the aggregator too
# ---------------------------------------------------------------------------

class TestPopulationAggregators:
    @pytest.mark.parametrize("agg", ["diloco", "semi_sync"])
    def test_population_loop_matches_batched_bitwise(self, agg):
        from repro.core import (make_population, make_population_task,
                                run_population)
        task = make_population_task(n_shards=4, n_train=1024, n_eval=256)
        cfg = FLConfig(rounds=12, eval_every=4, seed=0, aggregator=agg,
                       staleness_cap=2)
        hists = {}
        for engine in ("loop", "batched"):
            pop = make_population(task, n_devices=64, seed=0)
            hists[engine] = run_population(pop, cfg, h=4, m_cohort=8,
                                           engine=engine)
        assert hists["loop"].asdict() == hists["batched"].asdict()

    def test_population_semi_sync_wall_capped_by_deadline(self):
        from repro.core import (make_population, make_population_task,
                                run_population)
        task = make_population_task(n_shards=4, n_train=1024, n_eval=256)
        scn = Scenario(name="pop_strag",
                       straggler=StragglerSpec(slow_every=4, slowdown=3.0))
        kw = dict(h=4, m_cohort=8, engine="batched")
        pop_m = make_population(task, n_devices=64, seed=0, scenario=scn)
        hm = run_population(pop_m, FLConfig(rounds=16, eval_every=8, seed=0,
                                            scenario=scn), **kw)
        pop_s = make_population(task, n_devices=64, seed=0, scenario=scn)
        hs = run_population(
            pop_s, FLConfig(rounds=16, eval_every=8, seed=0, scenario=scn,
                            aggregator="semi_sync", staleness_cap=2), **kw)
        assert hs.server_wall_s[-1] < hm.server_wall_s[-1]
