"""Bench regression gate: fail CI when simulator throughput slows down.

Six gates, each naming the metric and file that tripped:

* **engine gate** -- the batched-engine ``device_steps_per_s`` rows of a
  freshly generated BENCH_sim.json vs the committed BENCH_baseline.json,
  keyed by (mode, engine, M);
* **task gate** -- the per-task ``device_steps_per_s`` rows of
  BENCH_tasks.json vs the committed BENCH_tasks_baseline.json, keyed by
  (task, engine, M).  cnn_mnist ran at ~3.4 device-steps/s in the smoke
  budget before the §10 hot-path work, one silent regression away from
  unusable, which is why tasks get their own gate;
* **population gate** -- the per-EF-store rows of BENCH_population.json vs
  BENCH_population_baseline.json, keyed by ef_store: ``ef_bytes_vs_dense``
  must not grow past baseline * (1 + tolerance) (the compressed stores'
  whole point is the memory ratio) and ``final_accuracy`` must not drop
  more than ``tolerance`` absolute.  Throughput is deliberately not gated
  here -- the population bench is dominated by host gather/scatter, too
  noisy at smoke budgets;
* **scenario gate** -- the (scenario, controller) ``final_accuracy`` rows
  of BENCH_scenarios.json vs the committed BENCH_scenarios_baseline.json.
  This is the DDPG-vs-fixed accuracy table: a controller change that
  quietly costs accuracy under ``gilbert_flaky`` or ``diurnal_cycle``
  trips here, not in a throughput number;
* **Pareto gate** -- mostly self-relative within BENCH_scenarios.json: on
  every scenario carrying a ``hetero_ddpg`` row (the per-device action
  space with pipelined decisions; bench_scenarios.PARETO_SCENARIOS), the
  heterogeneous fleet must dominate-or-match its fixed reference (the
  ``fixed_*`` fields embedded in the row -- a dedicated h=4 run at the
  same PARETO_ROUNDS budget) on at least one of ``energy_j`` / ``time_s``
  while giving up at most 2 points of ``final_accuracy`` -- the paper's
  claim that learned per-device control buys resource savings, not just a
  different operating point.  Additionally its pipelined
  ``wall_ratio_vs_fixed`` (controller wall clock over that reference's)
  must not regress past the *committed
  baseline's shared-DDPG* ratio: the pipelined per-device fleet may not
  cost more controller overhead than the blocking shared fleet did;
* **100M gate** -- the (aggregate, sparsity) frontier rows of
  BENCH_100m.json vs the committed BENCH_100m_baseline.json:
  ``wire_bytes_per_round_per_device`` must not grow past
  baseline * (1 + tolerance) (the analytic uplink budget is exact, so a
  trip means the k-budget clamp or the wire accounting changed) and
  ``loss_decrease`` must stay positive and above
  baseline * (1 - tolerance) (the 100M stack exists to learn under
  compression, not just to move fewer bytes);
* **async gate** -- self-relative within BENCH_async.json (no baseline
  file): under the straggler profiles ("stragglers",
  "flaky_stragglers" -- the ISSUE's "gilbert_flaky + stragglers") some
  async aggregator must beat the sync mean's simulated wall-clock while
  losing at most 2 points of final accuracy.  This is the headline claim
  of the semi-sync server (docs/ARCHITECTURE.md §11), gated so it cannot
  silently rot.

Exits nonzero when any matching row regresses more than ``--tolerance``
(default 30%; accuracy floors use the same number as an absolute drop).
Rows present on only one side are reported but never fail the gate (new
sweeps should not need a baseline update to land), and faster-than-baseline
rows print so improvements are visible in the CI log.  A missing baseline
file skips its gate with a note (the engine gate still runs).

When ``$GITHUB_STEP_SUMMARY`` is set (any GitHub Actions step), every gated
metric is also appended there as one markdown table -- value, baseline,
threshold, pass/fail -- so a red bench lane is diagnosable from the job
summary without scrolling the log.

The committed baselines were measured on a 2-core container -- slower than
the CI runners -- so the gates only trip on real order-of-magnitude
regressions (a lost jit, an accidental O(M) host loop), not runner jitter.
Refresh them (the recipe also lives in README.md's benchmarking section):

    python -m benchmarks.run --smoke
    cp BENCH_sim.json BENCH_baseline.json
    cp BENCH_tasks.json BENCH_tasks_baseline.json
    cp BENCH_population.json BENCH_population_baseline.json
    cp BENCH_scenarios.json BENCH_scenarios_baseline.json
    cp BENCH_100m.json BENCH_100m_baseline.json

BENCH_async.json needs no baseline copy: its gate is self-relative.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# one row per gated metric: (metric, key, value, baseline, threshold, ok);
# write_step_summary() renders them into $GITHUB_STEP_SUMMARY
SUMMARY_ROWS: list[tuple[str, str, str, str, str, bool]] = []


def _note(metric: str, key, value, baseline, threshold, ok: bool) -> None:
    SUMMARY_ROWS.append((metric, str(key), str(value), str(baseline),
                         str(threshold), ok))


def write_step_summary(path: str | None = None) -> None:
    """Append the gated-metric table to $GITHUB_STEP_SUMMARY (no-op when
    unset, e.g. local runs)."""
    path = path or os.environ.get("GITHUB_STEP_SUMMARY")
    if not path or not SUMMARY_ROWS:
        return
    lines = ["### Bench regression gate", "",
             "| metric | key | value | baseline | threshold | result |",
             "|---|---|---|---|---|---|"]
    for metric, key, value, baseline, threshold, ok in SUMMARY_ROWS:
        lines.append(f"| {metric} | {key} | {value} | {baseline} | "
                     f"{threshold} | {'pass' if ok else '**FAIL**'} |")
    with open(path, "a") as f:
        f.write("\n".join(lines) + "\n")


def _gate(base_rows: dict, current: dict, tolerance: float, key_of,
          row_filter, label: str) -> list[str]:
    """Generic throughput gate over ``device_steps_per_s`` rows; returns
    failure strings naming the metric, key and file that tripped."""
    seen, failures = set(), []
    for r in current["rows"]:
        if not row_filter(r):
            continue
        key = key_of(r)
        seen.add(key)
        b = base_rows.get(key)
        if b is None:
            print(f"  new row (no baseline): {key}  "
                  f"{r['device_steps_per_s']:.1f} device-steps/s")
            continue
        floor = b["device_steps_per_s"] * (1.0 - tolerance)
        ratio = r["device_steps_per_s"] / b["device_steps_per_s"]
        ok = r["device_steps_per_s"] >= floor
        verdict = "ok" if ok else "REGRESSED"
        print(f"  {verdict:>9}: {key}  baseline "
              f"{b['device_steps_per_s']:.1f} -> current "
              f"{r['device_steps_per_s']:.1f} device-steps/s  "
              f"({ratio:.2f}x, floor {floor:.1f})")
        _note(f"{label} device_steps_per_s", key,
              f"{r['device_steps_per_s']:.1f}",
              f"{b['device_steps_per_s']:.1f}", f">= {floor:.1f}", ok)
        if not ok:
            failures.append(f"{label} device_steps_per_s {key}: "
                            f"{ratio:.2f}x of baseline")
    for key in set(base_rows) - seen:
        if row_filter(base_rows[key]):
            print(f"  baseline row missing from current run: {key}")
    return failures


def check(baseline: dict, current: dict, tolerance: float,
          engines: tuple[str, ...] = ("batched",)) -> list[str]:
    """Engine gate: (mode, engine, M)-keyed rows of BENCH_sim.json."""
    base_rows = {(r["mode"], r["engine"], r["m_devices"]): r
                 for r in baseline["rows"]}
    return _gate(base_rows, current, tolerance,
                 key_of=lambda r: (r["mode"], r["engine"], r["m_devices"]),
                 row_filter=lambda r: r["engine"] in engines,
                 label="BENCH_sim.json")


def check_tasks(baseline: dict, current: dict, tolerance: float
                ) -> list[str]:
    """Task gate: (task, engine, M)-keyed rows of BENCH_tasks.json."""
    base_rows = {(r["task"], r["engine"], r["m_devices"]): r
                 for r in baseline["rows"]}
    return _gate(base_rows, current, tolerance,
                 key_of=lambda r: (r["task"], r["engine"], r["m_devices"]),
                 row_filter=lambda r: True,
                 label="BENCH_tasks.json")


def check_population(baseline: dict, current: dict, tolerance: float
                     ) -> list[str]:
    """Population gate: ef_bytes_vs_dense ratio + final_accuracy per
    ef_store row of BENCH_population.json.  Prints every row with its
    verdict so a trip names the exact store and metric."""
    base_rows = {r["ef_store"]: r for r in baseline["rows"]}
    failures = []
    for r in current["rows"]:
        key = r["ef_store"]
        b = base_rows.get(key)
        if b is None:
            print(f"  new row (no baseline): ef_store={key}")
            continue
        ceil_ratio = b["ef_bytes_vs_dense"] * (1.0 + tolerance)
        acc_floor = b["final_accuracy"] - tolerance
        bad_bytes = r["ef_bytes_vs_dense"] > ceil_ratio + 1e-12
        bad_acc = r["final_accuracy"] < acc_floor
        verdict = "REGRESSED" if (bad_bytes or bad_acc) else "ok"
        print(f"  {verdict:>9}: ef_store={key}  bytes_vs_dense "
              f"{b['ef_bytes_vs_dense']:.4f} -> {r['ef_bytes_vs_dense']:.4f}"
              f" (ceiling {ceil_ratio:.4f})  accuracy "
              f"{b['final_accuracy']:.4f} -> {r['final_accuracy']:.4f}"
              f" (floor {acc_floor:.4f})")
        _note("BENCH_population.json ef_bytes_vs_dense", f"ef_store={key}",
              f"{r['ef_bytes_vs_dense']:.4f}", f"{b['ef_bytes_vs_dense']:.4f}",
              f"<= {ceil_ratio:.4f}", not bad_bytes)
        _note("BENCH_population.json final_accuracy", f"ef_store={key}",
              f"{r['final_accuracy']:.4f}", f"{b['final_accuracy']:.4f}",
              f">= {acc_floor:.4f}", not bad_acc)
        if bad_bytes:
            failures.append(f"BENCH_population.json ef_bytes_vs_dense "
                            f"ef_store={key}: {r['ef_bytes_vs_dense']:.4f} "
                            f"> ceiling {ceil_ratio:.4f}")
        if bad_acc:
            failures.append(f"BENCH_population.json final_accuracy "
                            f"ef_store={key}: {r['final_accuracy']:.4f} "
                            f"< floor {acc_floor:.4f}")
    for key in set(base_rows) - {r["ef_store"] for r in current["rows"]}:
        print(f"  baseline row missing from current run: ef_store={key}")
    return failures


def check_scenarios(baseline: dict, current: dict, tolerance: float
                    ) -> list[str]:
    """Scenario gate: (scenario, controller)-keyed ``final_accuracy`` rows
    of BENCH_scenarios.json -- the DDPG-vs-fixed table.  Accuracy must not
    drop more than ``tolerance`` absolute below the committed baseline."""
    base_rows = {(r["scenario"], r["controller"]): r
                 for r in baseline["rows"]}
    seen, failures = set(), []
    for r in current["rows"]:
        key = (r["scenario"], r["controller"])
        seen.add(key)
        b = base_rows.get(key)
        if b is None:
            print(f"  new row (no baseline): {key}  "
                  f"accuracy {r['final_accuracy']:.4f}")
            continue
        floor = b["final_accuracy"] - tolerance
        ok = r["final_accuracy"] >= floor
        verdict = "ok" if ok else "REGRESSED"
        print(f"  {verdict:>9}: {key}  baseline "
              f"{b['final_accuracy']:.4f} -> current "
              f"{r['final_accuracy']:.4f}  (floor {floor:.4f})")
        _note("BENCH_scenarios.json final_accuracy", key,
              f"{r['final_accuracy']:.4f}", f"{b['final_accuracy']:.4f}",
              f">= {floor:.4f}", ok)
        if not ok:
            failures.append(f"BENCH_scenarios.json final_accuracy {key}: "
                            f"{r['final_accuracy']:.4f} < floor {floor:.4f}")
    for key in set(base_rows) - seen:
        print(f"  baseline row missing from current run: {key}")
    return failures


def check_pareto(baseline: dict | None, current: dict, tolerance: float,
                 acc_budget: float = 0.02) -> list[str]:
    """Pareto gate over the ``hetero_ddpg`` rows of BENCH_scenarios.json
    (see module docstring).  ``baseline`` supplies the committed shared-DDPG
    ``wall_ratio_vs_fixed`` ceiling; pass None to skip the wall check."""
    by_scen: dict[str, dict[str, dict]] = {}
    for r in current["rows"]:
        by_scen.setdefault(r["scenario"], {})[r["controller"]] = r
    base_rows = {(r["scenario"], r["controller"]): r
                 for r in (baseline["rows"] if baseline else [])}
    failures, gated = [], False
    for scen, rows in sorted(by_scen.items()):
        het = rows.get("hetero_ddpg")
        if het is None:
            continue
        gated = True
        # the fixed reference runs at the Pareto budget (PARETO_ROUNDS, not
        # the sweep's --rounds) and is embedded in the hetero row itself as
        # fixed_* fields -- the sweep's own fixed row is NOT comparable
        if "fixed_final_accuracy" not in het:
            failures.append(f"BENCH_scenarios.json pareto scenario={scen}: "
                            f"hetero_ddpg row without embedded fixed_* "
                            f"reference fields")
            _note("BENCH_scenarios.json pareto", scen, "no fixed_* fields",
                  "embedded fixed reference", "present", False)
            continue
        fixed = {"final_accuracy": het["fixed_final_accuracy"],
                 "energy_j": het["fixed_energy_j"],
                 "time_s": het["fixed_time_s"]}
        wins = [ax for ax in ("energy_j", "time_s") if het[ax] <= fixed[ax]]
        acc_floor = fixed["final_accuracy"] - acc_budget
        ok_acc = het["final_accuracy"] >= acc_floor
        ok = bool(wins) and ok_acc
        verdict = "ok" if ok else "FAILED"
        print(f"  {verdict:>9}: scenario={scen}  energy "
              f"{het['energy_j']:.2f} vs fixed {fixed['energy_j']:.2f}, "
              f"time {het['time_s']:.2f}s vs {fixed['time_s']:.2f}s "
              f"(wins: {wins or 'none'}), accuracy "
              f"{het['final_accuracy']:.4f} (floor {acc_floor:.4f})")
        _note("BENCH_scenarios.json pareto", scen,
              f"energy {het['energy_j']:.2f} / time {het['time_s']:.2f} / "
              f"acc {het['final_accuracy']:.4f}",
              f"fixed {fixed['energy_j']:.2f} / {fixed['time_s']:.2f} / "
              f"{fixed['final_accuracy']:.4f}",
              f"<= fixed on energy_j or time_s, acc >= fixed - {acc_budget}",
              ok)
        if not ok:
            failures.append(
                f"BENCH_scenarios.json pareto scenario={scen}: hetero_ddpg "
                f"beats fixed on {wins or 'neither axis'} with accuracy "
                f"{het['final_accuracy']:.4f} vs floor {acc_floor:.4f}")
        # pipelined controller overhead vs the committed shared-DDPG ratio
        b = base_rows.get((scen, "ddpg"))
        b_fix = base_rows.get((scen, "fixed"))
        ratio = het.get("wall_ratio_vs_fixed")
        if b is None or ratio is None:
            print(f"  wall-ratio check skipped for {scen}: no baseline "
                  f"ddpg row or no wall_ratio_vs_fixed")
            continue
        base_ratio = b.get("wall_ratio_vs_fixed")
        if base_ratio is None and b_fix is not None and b_fix["wall_s"] > 0:
            base_ratio = b["wall_s"] / b_fix["wall_s"]
        if base_ratio is None:
            print(f"  wall-ratio check skipped for {scen}: baseline has "
                  f"no derivable ddpg/fixed wall ratio")
            continue
        ceil = base_ratio * (1.0 + tolerance)
        ok_wall = ratio <= ceil
        verdict = "ok" if ok_wall else "REGRESSED"
        print(f"  {verdict:>9}: scenario={scen}  pipelined "
              f"wall_ratio_vs_fixed {ratio:.3f} vs committed shared-DDPG "
              f"{base_ratio:.3f} (ceiling {ceil:.3f})")
        _note("BENCH_scenarios.json pareto wall_ratio_vs_fixed", scen,
              f"{ratio:.3f}", f"{base_ratio:.3f}", f"<= {ceil:.3f}", ok_wall)
        if not ok_wall:
            failures.append(
                f"BENCH_scenarios.json pareto wall_ratio scenario={scen}: "
                f"{ratio:.3f} > ceiling {ceil:.3f} (baseline shared-DDPG "
                f"{base_ratio:.3f})")
    if not gated:
        failures.append("BENCH_scenarios.json pareto: no hetero_ddpg rows "
                        "found (bench_scenarios.PARETO_SCENARIOS not run?)")
        _note("BENCH_scenarios.json pareto", "hetero_ddpg rows", "none",
              "PARETO_SCENARIOS", "present", False)
    return failures


def check_100m(baseline: dict, current: dict, tolerance: float
               ) -> list[str]:
    """100M gate: (aggregate, sparsity)-keyed frontier rows of
    BENCH_100m.json.  Wire bytes are analytic (exact, no runner jitter) so
    the ceiling catches any change to the k-budget clamp or the wire
    accounting; loss_decrease must stay positive and within tolerance of
    the committed baseline so the compressed stack keeps learning."""
    base_rows = {(r["aggregate"], r["sparsity"]): r
                 for r in baseline["rows"]}
    seen, failures = set(), []
    for r in current["rows"]:
        key = (r["aggregate"], r["sparsity"])
        seen.add(key)
        b = base_rows.get(key)
        if b is None:
            print(f"  new row (no baseline): {key}  wire "
                  f"{r['wire_bytes_per_round_per_device']} B, "
                  f"loss_decrease {r['loss_decrease']:.4f}")
            continue
        wire_ceil = b["wire_bytes_per_round_per_device"] * (1.0 + tolerance)
        loss_floor = max(0.0, b["loss_decrease"] * (1.0 - tolerance))
        bad_wire = r["wire_bytes_per_round_per_device"] > wire_ceil
        bad_loss = not (r["loss_decrease"] > 0
                        and r["loss_decrease"] >= loss_floor)
        verdict = "REGRESSED" if (bad_wire or bad_loss) else "ok"
        print(f"  {verdict:>9}: {key}  wire "
              f"{b['wire_bytes_per_round_per_device']} -> "
              f"{r['wire_bytes_per_round_per_device']} B "
              f"(ceiling {wire_ceil:.0f})  loss_decrease "
              f"{b['loss_decrease']:.4f} -> {r['loss_decrease']:.4f} "
              f"(floor {loss_floor:.4f})")
        _note("BENCH_100m.json wire_bytes_per_round_per_device", key,
              str(r["wire_bytes_per_round_per_device"]),
              str(b["wire_bytes_per_round_per_device"]),
              f"<= {wire_ceil:.0f}", not bad_wire)
        _note("BENCH_100m.json loss_decrease", key,
              f"{r['loss_decrease']:.4f}", f"{b['loss_decrease']:.4f}",
              f"> 0 and >= {loss_floor:.4f}", not bad_loss)
        if bad_wire:
            failures.append(
                f"BENCH_100m.json wire_bytes {key}: "
                f"{r['wire_bytes_per_round_per_device']} > ceiling "
                f"{wire_ceil:.0f}")
        if bad_loss:
            failures.append(
                f"BENCH_100m.json loss_decrease {key}: "
                f"{r['loss_decrease']:.4f} not > 0 and >= floor "
                f"{loss_floor:.4f}")
    for key in set(base_rows) - seen:
        print(f"  baseline row missing from current run: {key}")
    return failures


def check_async(current: dict, acc_budget: float = 0.02) -> list[str]:
    """Async gate, self-relative within BENCH_async.json: under each
    straggler profile, at least one async aggregator row must beat the
    sync mean's simulated wall-clock (``sim_wall_clock_s``) while keeping
    ``final_accuracy >= mean - acc_budget``.  No baseline file -- the claim
    is about the aggregators relative to each other, so it holds or fails
    on any machine at any budget."""
    failures = []
    by_profile: dict[str, dict[str, dict]] = {}
    for r in current["rows"]:
        by_profile.setdefault(r["profile"], {})[r["aggregator"]] = r
    for profile in ("stragglers", "flaky_stragglers"):
        rows = by_profile.get(profile)
        if not rows or "mean" not in rows:
            failures.append(f"BENCH_async.json: no mean row for "
                            f"profile={profile}")
            _note("BENCH_async.json async beats sync", profile, "missing",
                  "mean row", "present", False)
            continue
        mean = rows["mean"]
        acc_floor = mean["final_accuracy"] - acc_budget
        winners = [a for a, r in rows.items() if a != "mean"
                   and r["sim_wall_clock_s"] < mean["sim_wall_clock_s"]
                   and r["final_accuracy"] >= acc_floor]
        for a, r in sorted(rows.items()):
            if a == "mean":
                continue
            print(f"  profile={profile} {a}: wall "
                  f"{r['sim_wall_clock_s']:.3f}s vs mean "
                  f"{mean['sim_wall_clock_s']:.3f}s, accuracy "
                  f"{r['final_accuracy']:.4f} (floor {acc_floor:.4f})")
        ok = bool(winners)
        verdict = "ok" if ok else "FAILED"
        best = min((rows[a]["sim_wall_clock_s"] for a in winners),
                   default=float("nan"))
        print(f"  {verdict:>9}: profile={profile}  async winners: "
              f"{winners or 'none'}")
        _note("BENCH_async.json async beats sync", profile,
              f"{winners} (best wall {best:.3f}s)" if winners else "none",
              f"mean wall {mean['sim_wall_clock_s']:.3f}s / "
              f"acc {mean['final_accuracy']:.4f}",
              f"wall < mean, acc >= mean - {acc_budget}", ok)
        if not ok:
            failures.append(
                f"BENCH_async.json profile={profile}: no async aggregator "
                f"beats mean's wall {mean['sim_wall_clock_s']:.3f}s within "
                f"{acc_budget} accuracy of {mean['final_accuracy']:.4f}")
    return failures


def _load_pair(base_path: str, cur_path: str, label: str):
    if os.path.exists(base_path) and os.path.exists(cur_path):
        with open(base_path) as f:
            baseline = json.load(f)
        with open(cur_path) as f:
            current = json.load(f)
        return baseline, current
    print(f"{label} gate skipped: {base_path} or {cur_path} not found")
    return None, None


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--current", default="BENCH_sim.json")
    ap.add_argument("--tasks-baseline", default="BENCH_tasks_baseline.json")
    ap.add_argument("--tasks-current", default="BENCH_tasks.json")
    ap.add_argument("--population-baseline",
                    default="BENCH_population_baseline.json")
    ap.add_argument("--population-current", default="BENCH_population.json")
    ap.add_argument("--scenarios-baseline",
                    default="BENCH_scenarios_baseline.json")
    ap.add_argument("--scenarios-current", default="BENCH_scenarios.json")
    ap.add_argument("--hundredm-baseline",
                    default="BENCH_100m_baseline.json")
    ap.add_argument("--hundredm-current", default="BENCH_100m.json")
    ap.add_argument("--async-current", default="BENCH_async.json")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed fractional drop in device_steps_per_s "
                         "(and absolute drop in gated accuracies)")
    args = ap.parse_args()
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)
    print(f"bench regression gate: tolerance {args.tolerance:.0%} "
          f"({args.baseline} vs {args.current})")
    failures = check(baseline, current, args.tolerance)
    tasks_baseline, tasks_current = _load_pair(
        args.tasks_baseline, args.tasks_current, "per-task")
    if tasks_baseline is not None:
        print(f"per-task gate: tolerance {args.tolerance:.0%} "
              f"({args.tasks_baseline} vs {args.tasks_current})")
        failures += check_tasks(tasks_baseline, tasks_current,
                                args.tolerance)
    pop_baseline, pop_current = _load_pair(
        args.population_baseline, args.population_current, "population")
    if pop_baseline is not None:
        print(f"population gate: tolerance {args.tolerance:.0%} "
              f"({args.population_baseline} vs {args.population_current})")
        failures += check_population(pop_baseline, pop_current,
                                     args.tolerance)
    scen_baseline, scen_current = _load_pair(
        args.scenarios_baseline, args.scenarios_current, "scenario")
    if scen_baseline is not None:
        print(f"scenario gate: tolerance {args.tolerance:.0%} "
              f"({args.scenarios_baseline} vs {args.scenarios_current})")
        failures += check_scenarios(scen_baseline, scen_current,
                                    args.tolerance)
    if scen_current is not None:
        print(f"pareto gate ({args.scenarios_current}, acc budget 0.02, "
              f"wall tolerance {args.tolerance:.0%})")
        failures += check_pareto(scen_baseline, scen_current,
                                 args.tolerance)
    hm_baseline, hm_current = _load_pair(
        args.hundredm_baseline, args.hundredm_current, "100M")
    if hm_baseline is not None:
        print(f"100M gate: tolerance {args.tolerance:.0%} "
              f"({args.hundredm_baseline} vs {args.hundredm_current})")
        failures += check_100m(hm_baseline, hm_current, args.tolerance)
    if os.path.exists(args.async_current):
        with open(args.async_current) as f:
            async_current = json.load(f)
        print(f"async gate (self-relative, {args.async_current})")
        failures += check_async(async_current)
    else:
        print(f"async gate skipped: {args.async_current} not found")
    write_step_summary()
    if failures:
        print("bench regression gate FAILED:")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print("bench regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
