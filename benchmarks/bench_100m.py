"""The 100M-stack frontier: bytes-on-wire vs device-steps/s.

Drives the ``qwen2_100m`` registry task across (sparsity, layer policy)
settings and publishes one row per point:

  * ``wire_bytes_per_round_per_device`` -- analytic uplink bytes from the
    clamped per-leaf channel budgets (launch.steps.lgc_wire_bytes_per_round);
  * ``collective_bytes_hlo`` -- what the COMPILED step actually moves, from
    the post-optimization HLO (analysis.roofline.collective_bytes_from_hlo
    + analysis.hlo_cost trip-count-aware totals), for one representative
    point per aggregate mode;
  * ``device_steps_per_s`` + the loss trajectory (compile excluded).

Each point runs in a fresh subprocess (same discipline as
bench_sharded_scaling): the host device count must be fixed before the
first jax backend init, and a fresh process also keeps the per-point
compile caches honest.

CI runs the smoke preset (same arch family, tiny dims) and gates the rows
against the committed BENCH_100m_baseline.json: wire-bytes ceiling and
loss-decrease floor (benchmarks/check_regression.py::check_100m).  The
full ~128M-parameter sweep is a manual run:

    PYTHONPATH=src python -m benchmarks.bench_100m --preset full --rounds 12

Timings use backend="exact": Pallas interpret mode on CPU is a parity
backend, 10-30x slower than the compiled oracle (ARCHITECTURE.md §12) --
routing through it would benchmark the interpreter, not the algorithm.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from .common import emit

# (aggregate, sparsity) frontier: the paper's 1%+2%+2% ladder, a 4x-fatter
# ladder, the bucket variant, the dense-psum ceiling and the FedAvg baseline
POINTS = (
    ("sparse_gather", (0.01, 0.02, 0.02)),
    ("sparse_gather", (0.04, 0.08, 0.08)),
    ("bucket_sparse", (0.01, 0.02, 0.02)),
    ("dense_masked", (0.01, 0.02, 0.02)),
    ("none", (0.01, 0.02, 0.02)),
)
# one representative HLO lowering per aggregate mode (an extra AOT compile
# each; the analytic wire numbers cover every point)
HLO_MODES = ("sparse_gather", "bucket_sparse", "dense_masked")


def _worker(aggregate: str, sparsity: tuple, preset: str, m_devices: int,
            rounds: int, seq: int, local_lr: float, with_hlo: bool) -> None:
    from repro.launch.compat import force_host_device_count
    force_host_device_count(m_devices)     # before first backend init
    import jax
    import jax.numpy as jnp
    from repro.models.paper_models import make_task

    task = make_task("qwen2_100m", m_devices=m_devices, preset=preset,
                     sparsity=sparsity, aggregate=aggregate,
                     local_lr=local_lr, seq=seq, backend="exact")
    out = task.run(rounds)
    losses = out["losses"]
    row = {
        "task": "qwen2_100m", "preset": preset, "aggregate": aggregate,
        "sparsity": "+".join(f"{f:g}" for f in sparsity),
        "m_devices": m_devices, "rounds": rounds,
        "param_count": out["param_count"],
        "wire_bytes_per_round_per_device":
            out["wire_bytes_per_round_per_device"],
        "device_steps_per_s": round(out["device_steps_per_s"], 3),
        "first_loss": round(losses[0], 4),
        "last_loss": round(losses[-1], 4),
        "loss_decrease": round(losses[0] - losses[-1], 4),
    }
    if with_hlo:
        from repro.analysis.hlo_cost import analyze_hlo
        from repro.analysis.roofline import collective_bytes_from_hlo
        b = task.build()
        x, y = b["pipe"].next_batch()
        batch = {"tokens": jnp.asarray(x), "labels": jnp.asarray(y)}
        received = jnp.ones((m_devices, task.step_cfg.n_channels), jnp.int32)
        text = (b["step"].lower(b["params"], b["ef"], batch, received)
                .compile().as_text())
        cost = analyze_hlo(text)
        row["collective_bytes_hlo"] = collective_bytes_from_hlo(text)
        row["hlo_flops"] = cost.flops
        row["hlo_bytes"] = cost.bytes
    print(json.dumps(row))


def _spawn(aggregate: str, sparsity: tuple, preset: str, m_devices: int,
           rounds: int, seq: int, local_lr: float, with_hlo: bool) -> dict:
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_100m", "--worker",
         "--aggregate", aggregate,
         "--sparsity", ",".join(str(f) for f in sparsity),
         "--preset", preset, "--m-devices", str(m_devices),
         "--rounds", str(rounds), "--seq", str(seq),
         "--local-lr", str(local_lr)]
        + ([] if with_hlo else ["--no-hlo"]),
        capture_output=True, text=True, env=os.environ.copy(), timeout=3600)
    if out.returncode != 0:
        raise RuntimeError(
            f"100m bench worker ({aggregate}, {sparsity}) failed:\n"
            + out.stderr[-2000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def run(preset: str = "smoke", m_devices: int = 4, rounds: int = 6,
        seq: int = 32, local_lr: float = 5e-3, with_hlo: bool = True,
        emit_csv: bool = True) -> dict:
    rows = []
    hlo_done: set = set()
    for aggregate, sparsity in POINTS:
        hlo = (with_hlo and aggregate in HLO_MODES
               and aggregate not in hlo_done)
        hlo_done.add(aggregate)
        row = _spawn(aggregate, sparsity, preset, m_devices, rounds, seq,
                     local_lr, hlo)
        rows.append(row)
        dense = row["param_count"] * 4
        wire = max(row["wire_bytes_per_round_per_device"], 1)
        if emit_csv:
            emit(f"lgc_100m_{aggregate}_{row['sparsity']}",
                 0.0 if row["device_steps_per_s"] == 0 else
                 1e6 / row["device_steps_per_s"],
                 f"wire_bytes={row['wire_bytes_per_round_per_device']};"
                 f"vs_dense={dense / wire:.0f}x;"
                 f"loss_decrease={row['loss_decrease']}")
    return {"bench": "lgc_100m", "rows": rows}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--aggregate", default="sparse_gather")
    ap.add_argument("--sparsity", default="0.01,0.02,0.02")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--m-devices", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--local-lr", type=float, default=5e-3)
    ap.add_argument("--no-hlo", action="store_true")
    ap.add_argument("--out", default="BENCH_100m.json")
    args = ap.parse_args(argv)
    sparsity = tuple(float(x) for x in args.sparsity.split(","))
    if args.worker:
        _worker(args.aggregate, sparsity, args.preset, args.m_devices,
                args.rounds, args.seq, args.local_lr, not args.no_hlo)
        return 0
    result = run(preset=args.preset, m_devices=args.m_devices,
                 rounds=args.rounds, seq=args.seq, with_hlo=not args.no_hlo)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"wrote {args.out} ({len(result['rows'])} rows)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
