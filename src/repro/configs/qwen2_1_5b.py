"""Qwen2-1.5B [arXiv:2407.10671] -- dense GQA kv=2, QKV bias, tied embed."""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-1.5b", arch_type="dense",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab_size=151_936,
    qkv_bias=True, tie_embeddings=True,
    mlp="swiglu", norm="rmsnorm",
    source="arXiv:2407.10671",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="qwen2-smoke", n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=2, d_ff=512, vocab_size=512, remat=False, attn_q_chunk=64)
