"""Shakespeare character-LM data (paper Fig. 6, RNN task).

The container is offline, so we embed a public-domain excerpt (sonnets +
play fragments) and tile it with light stochastic re-ordering to reach the
requested corpus size.  Character-level vocabulary mirrors the LEAF /
FedML Shakespeare setup the paper uses.

Federated sharding goes through :func:`char_shards`: the stream is first cut
into a disjoint train/test split (:func:`split_stream` -- the held-out
evaluation windows can never overlap a device shard), train windows are
drawn deterministically per seed, and each window carries a *region label*
(which tenth of the corpus it starts in -- the "which play" proxy) so the
standard federated partitioners (IID / label-subset / Dirichlet / quantity
skew, :mod:`repro.data.partition`) apply to character data unchanged.
Invariants -- disjointness, determinism, exact-partition pass-through -- are
pinned by tests/test_tasks.py (docs/ARCHITECTURE.md §5 explains how task
data feeds the engine-equivalence ladder).
"""
from __future__ import annotations

from typing import Callable

import numpy as np

_EXCERPT = """
Shall I compare thee to a summer's day?
Thou art more lovely and more temperate:
Rough winds do shake the darling buds of May,
And summer's lease hath all too short a date;
Sometime too hot the eye of heaven shines,
And often is his gold complexion dimm'd;
And every fair from fair sometime declines,
By chance or nature's changing course untrimm'd;
But thy eternal summer shall not fade,
Nor lose possession of that fair thou ow'st;
Nor shall death brag thou wander'st in his shade,
When in eternal lines to time thou grow'st:
So long as men can breathe or eyes can see,
So long lives this, and this gives life to thee.

To be, or not to be, that is the question:
Whether 'tis nobler in the mind to suffer
The slings and arrows of outrageous fortune,
Or to take arms against a sea of troubles
And by opposing end them. To die: to sleep;
No more; and by a sleep to say we end
The heart-ache and the thousand natural shocks
That flesh is heir to, 'tis a consummation
Devoutly to be wish'd. To die, to sleep;
To sleep: perchance to dream: ay, there's the rub;
For in that sleep of death what dreams may come
When we have shuffled off this mortal coil,
Must give us pause: there's the respect
That makes calamity of so long life;

All the world's a stage,
And all the men and women merely players;
They have their exits and their entrances,
And one man in his time plays many parts,
His acts being seven ages. At first the infant,
Mewling and puking in the nurse's arms;
And then the whining school-boy, with his satchel
And shining morning face, creeping like snail
Unwillingly to school. And then the lover,
Sighing like furnace, with a woeful ballad
Made to his mistress' eyebrow. Then a soldier,
Full of strange oaths, and bearded like the pard,
Jealous in honour, sudden and quick in quarrel,
Seeking the bubble reputation
Even in the cannon's mouth.

Friends, Romans, countrymen, lend me your ears;
I come to bury Caesar, not to praise him.
The evil that men do lives after them;
The good is oft interred with their bones;
So let it be with Caesar. The noble Brutus
Hath told you Caesar was ambitious:
If it were so, it was a grievous fault,
And grievously hath Caesar answer'd it.

Now is the winter of our discontent
Made glorious summer by this sun of York;
And all the clouds that lour'd upon our house
In the deep bosom of the ocean buried.
Now are our brows bound with victorious wreaths;
Our bruised arms hung up for monuments;
Our stern alarums changed to merry meetings,
Our dreadful marches to delightful measures.
"""

CHAR_VOCAB = sorted(set(_EXCERPT))
_STOI = {c: i for i, c in enumerate(CHAR_VOCAB)}
VOCAB_SIZE = len(CHAR_VOCAB)


def load_shakespeare(n_chars: int = 200_000, seed: int = 0) -> np.ndarray:
    """Return an int32 token stream of ~n_chars characters."""
    rng = np.random.default_rng(seed)
    lines = [l for l in _EXCERPT.strip().split("\n\n")]
    chunks = []
    total = 0
    while total < n_chars:
        li = rng.integers(0, len(lines))
        chunks.append(lines[li] + "\n\n")
        total += len(chunks[-1])
    text = "".join(chunks)[:n_chars]
    return np.array([_STOI[c] for c in text], np.int32)


def char_batches(stream: np.ndarray, batch: int, seq: int,
                 rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray]:
    """Sample (inputs, targets) next-char pairs of shape (batch, seq)."""
    x, y, _ = char_windows(stream, batch, seq, rng)
    return x, y


def char_windows(stream: np.ndarray, n: int, seq: int,
                 rng: np.random.Generator
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """``char_batches`` that also returns the window start positions, so
    callers can derive position-based metadata (region labels, overlap
    checks)."""
    starts = rng.integers(0, stream.shape[0] - seq - 1, n)
    x = np.stack([stream[s:s + seq] for s in starts])
    y = np.stack([stream[s + 1:s + seq + 1] for s in starts])
    return x, y, starts


def split_stream(stream: np.ndarray, test_frac: float = 0.15
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Cut a character stream into disjoint (train, test) tails.

    The test split is the *tail* of the stream, carved before any window is
    drawn, so held-out evaluation sequences share no character position with
    any device shard (pinned by
    tests/test_tasks.py::TestShakespeareTask::test_eval_split_is_disjoint).

    Caveat: the guarantee is *positional*, not content-level.  The embedded
    corpus tiles a ~2.5 KB excerpt to size, so most eval windows have
    byte-identical twins in the train region; eval numbers on the synthetic
    stream measure fitting, not generalization.  Swap in a real corpus
    (one untiled pass of text) and the positional split becomes a true
    held-out split with no code change.
    """
    if not 0.0 < test_frac < 1.0:
        raise ValueError(f"test_frac must be in (0, 1), got {test_frac}")
    cut = stream.shape[0] - max(1, int(round(stream.shape[0] * test_frac)))
    if cut < 1:
        raise ValueError(
            f"stream of {stream.shape[0]} chars leaves no train split at "
            f"test_frac={test_frac}")
    return stream[:cut], stream[cut:]


N_REGIONS = 10   # corpus tenths used as the "which play" pseudo-labels


def char_shards(stream: np.ndarray, m_devices: int, *, seq: int,
                n_train: int, n_eval: int, seed: int,
                partition_fn: Callable[[np.ndarray, np.ndarray, int, int],
                                       list],
                test_frac: float = 0.15
                ) -> tuple[list[tuple[np.ndarray, np.ndarray]],
                           tuple[np.ndarray, np.ndarray]]:
    """Deterministic federated shards + held-out eval batch for a char LM.

    1. ``split_stream`` carves a positionally disjoint test tail (see its
       docstring for the content-duplication caveat of the tiled synthetic
       corpus).
    2. ``n_train`` (input, target) windows of length ``seq`` are drawn from
       the train split with ``default_rng(seed)`` -- fully deterministic.
       The eval windows come from an *independent* generator, so the
       held-out set is a fixed function of (seed, seq, n_eval) and stays
       comparable across train budgets.
    3. Each window is labeled with the corpus region (tenth) it starts in,
       and ``partition_fn(x, labels, m, seed)`` -- any of the
       :mod:`repro.data.partition` / :mod:`repro.data.mnist` partitioners --
       deals the windows to devices by that label, giving character data the
       same statistical-heterogeneity controls as MNIST.
    4. The eval batch is drawn from the test split only.
    """
    train, test = split_stream(stream, test_frac)
    if test.shape[0] <= seq + 1 or train.shape[0] <= seq + 1:
        raise ValueError(
            f"splits of {train.shape[0]}/{test.shape[0]} chars are shorter "
            f"than seq+1={seq + 1}; lower seq or test_frac")
    rng = np.random.default_rng(seed)
    x, y, starts = char_windows(train, n_train, seq, rng)
    regions = (starts.astype(np.int64) * N_REGIONS
               // train.shape[0]).astype(np.int32)
    # partition *indices* by region label, then gather the windows: the
    # partitioners see (index-column, label) arrays, so their exact-partition
    # and determinism guarantees transfer unchanged
    idx_shards = partition_fn(np.arange(n_train, dtype=np.int64)[:, None],
                              regions, m_devices, seed)
    shards = [(x[ids[:, 0]], y[ids[:, 0]]) for ids, _ in idx_shards]
    # independent stream: the eval set must not move when n_train (or any
    # other train-side draw) changes
    xte, yte, _ = char_windows(test, n_eval, seq,
                               np.random.default_rng((seed, 0xE7A1)))
    return shards, (xte, yte)
