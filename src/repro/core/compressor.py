"""LGC compressors (paper §2.1).

Implements, in pure JAX:

* ``top_k(x, k)``               -- classic Top_k sparsifier (Eq. before (1)).
* ``top_alpha_beta(x, a, b)``   -- Top_{alpha,beta}: keep coordinates whose
                                   |x_i| rank lies in (alpha, beta]  (Eq. (1)).
* ``lgc_layers(x, ks)``         -- the C disjoint layers
                                   {Top_{K_{c-1}, K_c}(x)}_{c=1..C}  (Eq. (2)).
* ``lgc_compress(x, ks, mask)`` -- LGC_k(x) = sum of the *received* layers.

Rank semantics follow the paper: thr_alpha is the alpha-th largest absolute
value, and Top_{alpha,beta} keeps thr_alpha >= |x_i| > thr_beta.  We resolve
ties by strict rank (jnp.argsort of -|x|), which makes layers exactly disjoint
and sum(layers) == top_{K_C}(x) -- the property the server decode relies on.

Histogram-threshold selection (the TPU-native approximation used by the
Pallas kernels) lives in ``repro.kernels``; this module is the exact oracle.

Invariants: layer disjointness / rank semantics are pinned by
tests/test_compressor.py, and ``lgc_compress_topk`` (the argsort-free
selection the batched engine uses) must stay exactly rank-equivalent to
``lgc_compress`` (tests/test_compressor.py::TestTracedSelection) -- it
feeds the engine-equivalence ladder (docs/ARCHITECTURE.md §1).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# pytree <-> flat vector helpers
# ---------------------------------------------------------------------------

def tree_size(tree) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def flatten_tree(tree) -> Array:
    """Concatenate all leaves into one flat f32 vector (stable leaf order)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])


def unflatten_like(flat: Array, tree):
    """Inverse of :func:`flatten_tree` against a reference pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out, off = [], 0
    for l in leaves:
        n = int(l.size)
        out.append(jnp.reshape(flat[off:off + n], l.shape).astype(l.dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# rank-exact compressors (paper semantics)
# ---------------------------------------------------------------------------

def _rank_of(x: Array) -> Array:
    """rank[i] = 0-based rank of |x_i| among all coordinates (0 = largest).

    Strict total order (argsort tie-break) so that rank-range selections are
    exactly disjoint.
    """
    order = jnp.argsort(-jnp.abs(x))          # indices sorted by |x| desc
    rank = jnp.zeros_like(order).at[order].set(jnp.arange(x.shape[0]))
    return rank


def top_k(x: Array, k: int) -> Array:
    """Keep the k largest-|.| coordinates of x, zero the rest."""
    if k <= 0:
        return jnp.zeros_like(x)
    if k >= x.shape[0]:
        return x
    rank = _rank_of(x)
    return jnp.where(rank < k, x, 0.0)


def top_alpha_beta(x: Array, alpha: int, beta: int) -> Array:
    """Top_{alpha,beta}: keep coordinates ranked in (alpha, beta] by |.|.

    Paper Eq. (1) keeps thr_alpha >= |x_i| > thr_beta where thr_j is the j-th
    largest absolute value; in strict-rank terms that is
    ``alpha - 1 <= rank < beta`` with 1-based (alpha, beta].  We expose the
    0-based half-open rank interval [alpha, beta) which matches
    Top_{alpha+1..beta} of the paper and composes cleanly into layers.
    """
    rank = _rank_of(x)
    return jnp.where((rank >= alpha) & (rank < beta), x, 0.0)


def lgc_layers(x: Array, ks: Sequence[int]) -> list[Array]:
    """Split x into C disjoint layers; layer c keeps ranks [K_{c-1}, K_c).

    ks are the per-channel coordinate budgets k_c (paper's traffic
    allocation vector k).  sum(layers) == top_k(x, sum(ks)).
    """
    rank = _rank_of(x)
    layers, lo = [], 0
    for k in ks:
        hi = lo + int(k)
        layers.append(jnp.where((rank >= lo) & (rank < hi), x, 0.0))
        lo = hi
    return layers


def lgc_compress(x: Array, ks: Sequence[int],
                 received: Sequence[bool] | None = None) -> Array:
    """LGC_k(x) (paper Eq. (2)): sum of layers that actually arrived.

    ``received[c]`` models channel c delivering its layer; default all True
    (ideal channels), in which case LGC_k(x) == Top_{sum(ks)}(x).
    """
    layers = lgc_layers(x, ks)
    if received is None:
        received = [True] * len(layers)
    out = jnp.zeros_like(x)
    for layer, ok in zip(layers, received):
        out = out + (layer if ok else jnp.zeros_like(layer))
    return out


def lgc_compress_topk(u: Array, ks: Array, received: Array,
                      k_cap: int) -> Array:
    """:func:`lgc_compress_traced` without the full argsort.

    A (M=64, D=7850) argsort costs ~190 ms on XLA:CPU while ``lax.top_k``
    with k=400 costs ~12 ms, so the batched engine's sync block selects
    layers by *threshold*: the b-th largest |u| plus an index-order cumsum
    to split ties, which reproduces the stable-argsort rank semantics
    exactly on every coordinate that matters (ties among |u| values are
    broken by ascending index in both formulations; coordinates with
    u == 0 may differ in mask membership but contribute 0 either way).

    ``k_cap`` is a static bound with k_cap >= min(max(cumsum(ks)), D);
    callers round it up to a power of two so DDPG budget changes do not
    recompile.
    """
    a = jnp.abs(u)
    d = u.shape[0]
    vals = jax.lax.top_k(a, min(k_cap, d))[0]          # descending |u|
    cum = jnp.cumsum(ks.astype(jnp.int32))

    def rank_below(b):
        """Boolean mask of {i : rank(|u_i|) < b} (b traced)."""
        bc = jnp.clip(b, 1, vals.shape[0])
        thr = vals[bc - 1]                             # b-th largest value
        gt = a > thr
        eq = a == thr
        tied_take = bc - jnp.sum(gt)                   # ties to include
        pos = jnp.cumsum(eq)                           # 1-based index order
        sel = gt | (eq & (pos <= tied_take))
        sel = jnp.where(b > 0, sel, jnp.zeros_like(sel))
        return jnp.where(b >= d, jnp.ones_like(sel), sel)

    g = jnp.zeros_like(u)
    prev = jnp.zeros(a.shape, bool)
    for c in range(ks.shape[0]):       # static unroll over C channels
        cur = rank_below(cum[c])
        g = g + jnp.where(cur & ~prev & received[c], u, 0.0)
        prev = cur
    return g


def lgc_compress_traced(u: Array, ks: Array, received: Array) -> Array:
    """LGC_k(u) with *traced* layer budgets and delivery mask.

    Same rank semantics as :func:`lgc_compress` but with ``ks`` ((C,) int32)
    and ``received`` ((C,) bool) as traced values; only the layer *count* C
    is static.  This is the readable rank-based reference that
    :func:`lgc_compress_topk` (the argsort-free variant the batched engine
    actually runs) must match bit-for-bit --
    tests/test_compressor.py::TestTracedSelection pins all three against
    each other.
    """
    rank = _rank_of(u)
    cum = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                           jnp.cumsum(ks.astype(jnp.int32))])
    g = jnp.zeros_like(u)
    for c in range(ks.shape[0]):       # static unroll over C channels
        sel = (rank >= cum[c]) & (rank < cum[c + 1])
        g = g + jnp.where(sel & received[c], u, 0.0)
    return g


# ---------------------------------------------------------------------------
# per-model-layer budgets (structure-aware compression)
# ---------------------------------------------------------------------------
#
# The LGC channel layers above rank coordinates *globally*: a conv kernel
# competes with the fc matrix for the same top-k slots, and whole model
# layers can go silent for rounds.  The per-layer path first allocates the
# round's budget k_total across MODEL layers (the pytree leaves) under a
# registered policy, selects the top-b_l coordinates inside each layer, and
# only then splits the selected candidates across channels with the
# unchanged magnitude layering -- following layer-divergence feedback
# aggregation (arXiv:2404.08324) and FedGreen's fine-grained per-layer
# compression (arXiv:2111.06146).
#
# Contract (tests/test_compressor.py::TestPerLayer):
# * candidate masks of distinct layers are disjoint (they live in disjoint
#   slices) and sum(budgets) == k_total for "uniform" always and for
#   "size_prop" whenever k_total <= D;
# * the "uniform" policy (uniform magnitude threshold across layers ==
#   per-layer budgets set to the global top-k's per-layer hit counts) is
#   BIT-equivalent to the global path: per_layer_compress(u, ...) equals
#   lgc_compress_topk(u, ...) exactly, which is what lets
#   FLConfig.layer_policy ride the engine-equivalence ladder.

#: flat segments at least this large route through the Pallas kernels when
#: ``backend="pallas"`` -- below it the (rows, 128) marshalling costs more
#: than the kernel saves (ROADMAP item 2 measures the 10^8 regime)
PALLAS_MIN_ELEMS = 100_000


def tree_layer_slices(tree, skip_leading_axes: int = 0
                      ) -> list[tuple[str, int, int]]:
    """``(name, lo, hi)`` half-open slices of each pytree leaf inside the
    :func:`flatten_tree` vector, in leaf order.

    ``skip_leading_axes=1`` treats the leaves as stacked per-device state
    ((M, ...) arrays) and describes the per-device flat vector -- the shape
    the engines' compression rows actually have."""
    leaves, _ = jax.tree_util.tree_flatten(tree)
    paths = jax.tree_util.tree_leaves_with_path(tree)
    out, lo = [], 0
    for (path, leaf) in paths:
        shape = leaf.shape[skip_leading_axes:]
        n = 1
        for s in shape:
            n *= int(s)
        name = jax.tree_util.keystr(path)
        out.append((name, lo, lo + n))
        lo += n
    assert len(out) == len(leaves)
    return out


def _topb_mask(a: Array, b: Array, k_cap: int) -> Array:
    """Boolean mask of the ``b`` largest entries of ``a`` (absolute values
    already taken), ties split by ascending index -- the same stable-rank
    semantics as :func:`lgc_compress_topk`'s ``rank_below``.  ``b`` is
    traced, ``k_cap`` static with b <= k_cap."""
    n = a.shape[0]
    vals = jax.lax.top_k(a, min(k_cap, n))[0]
    bc = jnp.clip(b, 1, vals.shape[0])
    thr = vals[bc - 1]
    gt = a > thr
    eq = a == thr
    tied_take = bc - jnp.sum(gt)
    pos = jnp.cumsum(eq)
    sel = gt | (eq & (pos <= tied_take))
    sel = jnp.where(b > 0, sel, jnp.zeros_like(sel))
    return jnp.where(b >= n, jnp.ones_like(sel), sel)


def _largest_remainder(weights: Array, sizes: Array, k_total: Array) -> Array:
    """Apportion ``k_total`` coordinates over layers proportionally to
    ``weights``, by largest-remainder rounding, capped at layer sizes.

    Exact (sum == k_total) whenever no layer's quota exceeds its size --
    always true for size-proportional weights with k_total <= D; heavily
    skewed divergence weights may undershoot after the cap (the remainder
    pass hands out at most one extra coordinate per layer)."""
    w = jnp.maximum(weights.astype(jnp.float32), 0.0)
    tot = jnp.sum(w)
    quota = jnp.where(tot > 0, k_total * w / jnp.where(tot > 0, tot, 1.0),
                      k_total * sizes.astype(jnp.float32)
                      / jnp.sum(sizes.astype(jnp.float32)))
    base = jnp.minimum(jnp.floor(quota).astype(jnp.int32), sizes)
    rem = k_total - jnp.sum(base)
    frac = quota - jnp.floor(quota)
    headroom = (sizes - base) > 0
    # one extra coordinate to the `rem` layers with the largest remainders
    # (index-ascending tie-break via argsort stability), headroom permitting
    order = jnp.argsort(-jnp.where(headroom, frac, -1.0))
    rank = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
    extra = (rank < rem) & headroom
    return base + extra.astype(jnp.int32)


def layer_budgets(policy: str, u: Array,
                  slices: Sequence[tuple[str, int, int]],
                  k_total: Array, k_cap: int) -> Array:
    """Per-model-layer coordinate budgets ``(L,) int32`` under ``policy``.

    Policies (:data:`LAYER_POLICIES`):

    * ``"uniform"``    -- one magnitude threshold across all layers: budgets
      are the per-layer hit counts of the global top-``k_total`` selection,
      so the induced compression is bit-equal to the global path.
    * ``"size_prop"``  -- b_l proportional to layer size (every layer keeps
      the same fraction of itself).
    * ``"divergence"`` -- b_l proportional to the layer's update mass
      ||u_l||_2 (layer-divergence feedback, arXiv:2404.08324): layers whose
      accumulated update diverges most from the global model get the budget.
    """
    sizes = jnp.asarray([hi - lo for _, lo, hi in slices], jnp.int32)
    if policy == "uniform":
        mask = _topb_mask(jnp.abs(u), k_total, k_cap)
        return jnp.asarray([jnp.sum(mask[lo:hi], dtype=jnp.int32)
                            for _, lo, hi in slices])
    if policy == "size_prop":
        return _largest_remainder(sizes.astype(jnp.float32), sizes, k_total)
    if policy == "divergence":
        norms = jnp.asarray([jnp.sqrt(jnp.sum(u[lo:hi] ** 2))
                             for _, lo, hi in slices])
        return _largest_remainder(norms, sizes, k_total)
    raise ValueError(f"unknown layer policy {policy!r}; registered: "
                     f"{sorted(LAYER_POLICIES)}")


#: registry of per-model-layer budget policies (see :func:`layer_budgets`)
LAYER_POLICIES: dict[str, str] = {
    "uniform": "global magnitude threshold (bit-equal to global top-k)",
    "size_prop": "budgets proportional to layer size",
    "divergence": "budgets proportional to layer update mass ||u_l||_2",
}


def per_layer_candidates(u: Array, slices: Sequence[tuple[str, int, int]],
                         budgets: Array, k_cap: int) -> Array:
    """Boolean candidate mask: top-``budgets[l]`` by |u| inside each layer
    slice, stable-rank tie split per layer.  Masks of different layers are
    disjoint by construction."""
    a = jnp.abs(u)
    parts = [_topb_mask(a[lo:hi], budgets[i], min(k_cap, hi - lo))
             for i, (_, lo, hi) in enumerate(slices)]
    return jnp.concatenate(parts)


def per_layer_candidates_hist(u: Array,
                              slices: Sequence[tuple[str, int, int]],
                              budgets: Array,
                              pallas_min_elems: int = PALLAS_MIN_ELEMS,
                              interpret: bool = True) -> Array:
    """Histogram-threshold candidate mask (the Pallas backend's selection).

    Each layer's threshold comes from the 256-bin magnitude histogram --
    the same 2-pass approximation :func:`repro.kernels.lgc_compress_hist`
    uses for channel layers -- so selected counts are bin-granular, not
    exact.  Layers with at least ``pallas_min_elems`` coordinates route
    through the Pallas ``maxabs``/``histogram`` kernels (where the fused
    row-blocked passes pay off); smaller layers use the bit-identical
    :mod:`repro.kernels.ref` oracles, so the routing threshold never
    changes the result (tests/test_kernels.py::TestPerLayerHistParity)."""
    from repro.kernels import histogram, maxabs
    from repro.kernels.ref import (hist_counts, hist_maxabs,
                                   hist_thresholds)
    parts = []
    for i, (_, lo, hi) in enumerate(slices):
        seg = u[lo:hi]
        cum = budgets[i].reshape((1,)).astype(jnp.int32)
        if hi - lo >= pallas_min_elems:
            mx = maxabs(seg, interpret=interpret)
            counts = histogram(seg, mx, interpret=interpret)
            mx = mx.reshape(())
        else:
            mx = hist_maxabs(seg)
            counts = hist_counts(seg, mx)
        thr = hist_thresholds(counts, mx, cum)[0]
        # strict > thr: same keep rule as ref.hist_layered_sparsify
        parts.append((jnp.abs(seg) > thr) & (budgets[i] > 0))
    return jnp.concatenate(parts)


def per_layer_compress(u: Array, ks: Array, received: Array,
                       slices: Sequence[tuple[str, int, int]],
                       policy: str, k_cap: int) -> Array:
    """Structure-aware LGC: per-layer budgets -> per-layer top-b_l candidate
    mask -> the unchanged channel layering over the masked vector.

    Under ``policy="uniform"`` this is bit-equal to
    ``lgc_compress_topk(u, ks, received, k_cap)`` -- the candidate set is
    exactly the global top-k_total, and every channel layer lives inside it
    (tests/test_compressor.py::TestPerLayer).  Other policies reshape WHICH
    coordinates compete, not how many: sum(ks) coordinates still cross the
    channels, so the engines' byte accounting is policy-independent."""
    k_total = jnp.sum(ks.astype(jnp.int32))
    if policy == "uniform":
        # shortcut: the global mask IS the union of the per-layer masks
        mask = _topb_mask(jnp.abs(u), k_total, k_cap)
    else:
        budgets = layer_budgets(policy, u, slices, k_total, k_cap)
        mask = per_layer_candidates(u, slices, budgets, k_cap)
    return lgc_compress_topk(jnp.where(mask, u, 0.0), ks, received, k_cap)


def per_layer_wire_bytes(budgets: Sequence[int],
                         slices: Sequence[tuple[str, int, int]],
                         value_bytes: int = 4) -> int:
    """Bytes on the wire for the per-layer sparse format.

    Per-layer indices are *layer-local*, so each costs
    ceil(log2(layer_size)) bits instead of the flat format's 4 bytes --
    the honest bytes-on-wire win structure-aware compression buys at equal
    k (reported per policy by benchmarks/bench_tasks.py)."""
    total = 0
    for b, (_, lo, hi) in zip(budgets, slices):
        idx_bytes = max(1, -(-max(hi - lo, 2).bit_length() // 8))
        total += int(b) * (value_bytes + idx_bytes)
    return total


# ---------------------------------------------------------------------------
# sparse wire format -- what actually crosses a channel
# ---------------------------------------------------------------------------

def layer_to_sparse(layer_dense: Array, k: int, x: Array,
                    lo: int) -> tuple[Array, Array]:
    """Extract fixed-size (values, indices) for a layer from the full vector.

    Used for wire-byte accounting and for the sparse_gather collective mode:
    the k coordinates ranked [lo, lo+k) of |x|.
    """
    rank = _rank_of(x)
    # position p gets the index whose rank == lo + p
    order = jnp.argsort(rank)            # order[r] = index with rank r
    idx = jax.lax.dynamic_slice_in_dim(order, lo, k)
    vals = x[idx]
    del layer_dense
    return vals, idx


def sparse_to_dense(vals: Array, idx: Array, d: int) -> Array:
    """Scatter (values, indices) back to a dense D-vector (server decode)."""
    return jnp.zeros((d,), vals.dtype).at[idx].set(vals)


def wire_bytes(ks: Sequence[int], value_bytes: int = 4,
               index_bytes: int = 4) -> list[int]:
    """Bytes on the wire per channel for the sparse format."""
    return [int(k) * (value_bytes + index_bytes) for k in ks]


# ---------------------------------------------------------------------------
# compressor objects (used by the FL loop and the distributed step)
# ---------------------------------------------------------------------------

class LGCCompressor:
    """Stateless layered compressor bound to layer budgets ``ks``.

    gamma (paper's contraction coefficient) for Top_K satisfies
    E||u - C(u)||^2 <= (1 - K/D)||u||^2, i.e. gamma = K/D in the worst case.
    """

    def __init__(self, ks: Sequence[int]):
        self.ks = [int(k) for k in ks]
        self.k_total = sum(self.ks)

    def gamma(self, d: int) -> float:
        return min(1.0, self.k_total / max(d, 1))

    def __call__(self, u: Array, received: Sequence[bool] | None = None) -> Array:
        return lgc_compress(u, self.ks, received)

    def layers(self, u: Array) -> list[Array]:
        return lgc_layers(u, self.ks)

    def sparse_layers(self, u: Array) -> list[tuple[Array, Array]]:
        out, lo = [], 0
        for k in self.ks:
            out.append(layer_to_sparse(None, k, u, lo))
            lo += k
        return out

    def wire_bytes(self) -> list[int]:
        return wire_bytes(self.ks)


@functools.partial(jax.jit, static_argnums=(1,))
def topk_jit(x: Array, k: int) -> Array:
    return top_k(x, k)


# ---------------------------------------------------------------------------
# QSGD quantization (Alistarh et al. 2017, cited by the paper §5.1) --
# composes with LGC: the selected layer values are quantized to s levels
# with unbiased stochastic rounding before transmission; the quantization
# residual joins the error-feedback memory like any other compression error.
# ---------------------------------------------------------------------------

def qsgd_quantize(x: Array, key: Array, levels: int = 255
                  ) -> tuple[Array, Array]:
    """Unbiased stochastic quantization: returns (q int8/int16 codes, scale).

    q_i in [-levels/2, levels/2], E[dequantize(q)] == x elementwise.
    """
    scale = jnp.max(jnp.abs(x)) + 1e-30
    half = levels // 2
    y = x / scale * half                       # in [-half, half]
    lo = jnp.floor(y)
    p = y - lo                                 # P(round up)
    up = jax.random.uniform(key, x.shape) < p
    q = (lo + up.astype(jnp.float32)).astype(jnp.int32)
    q = jnp.clip(q, -half, half)
    return q, scale


def qsgd_dequantize(q: Array, scale: Array, levels: int = 255) -> Array:
    half = levels // 2
    return q.astype(jnp.float32) * (scale / half)
