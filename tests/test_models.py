"""Per-architecture smoke tests (reduced configs) + layer-level correctness:
MoE sort-dispatch vs dense oracle, SSD chunked-scan vs step recurrence,
decode-vs-full-forward consistency, sliding-window ring cache."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.models import transformer as tf
from repro.models import moe as moe_lib
from repro.models import ssd as ssd_lib
from repro.models.layers import KVCache, attention_decode, attention_train, cache_update

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    batch = {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
             "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)}
    if cfg.arch_type == "vlm":
        batch["prefix"] = jax.random.normal(KEY, (b, cfg.n_prefix_tokens, 1024))
    if cfg.arch_type == "audio":
        batch["prefix"] = jax.random.normal(KEY, (b, cfg.encoder_seq,
                                                  cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", list_archs())
class TestArchSmoke:
    def test_train_step_shapes_and_finite(self, arch):
        cfg = get_smoke_config(arch)
        params = tf.init_params(cfg, KEY)
        batch = _batch(cfg)
        loss, grads = jax.value_and_grad(
            lambda p: tf.lm_loss(p, cfg, batch))(params)
        assert np.isfinite(float(loss))
        for leaf in jax.tree_util.tree_leaves(grads):
            assert np.all(np.isfinite(np.asarray(leaf, np.float32)))

    def test_decode_step(self, arch):
        cfg = get_smoke_config(arch)
        params = tf.init_params(cfg, KEY)
        b = 2
        cache = tf.init_cache(cfg, b, 64)
        cache["pos"] = jnp.int32(5)
        if cfg.arch_type == "audio":
            for k in ("cross_k", "cross_v"):
                cache[k] = jax.random.normal(KEY, cache[k].shape
                                             ).astype(cfg.dtype)
        tok = jax.random.randint(KEY, (b, 1), 0, cfg.vocab_size)
        logits, cache2 = tf.decode_step(params, cfg, tok, cache)
        assert logits.shape == (b, cfg.vocab_padded)
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))
        assert int(cache2["pos"]) == 6


class TestDecodeConsistency:
    """Sequential decode from an empty cache must match the parallel
    (training-mode) forward pass -- position by position."""

    @pytest.mark.parametrize("arch", ["qwen2-1.5b", "mamba2-370m",
                                      "olmoe-1b-7b"])
    def test_decode_matches_forward(self, arch):
        # generous MoE capacity: forward-mode drops would differ from the
        # per-token decode path (expected divergence, not a bug)
        cfg = dataclasses.replace(get_smoke_config(arch), remat=False,
                                  moe_capacity_factor=8.0)
        params = tf.init_params(cfg, KEY)
        b, s = 1, 12
        toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0,
                                  cfg.vocab_size)
        hidden, _, _ = tf.forward_hidden(params, cfg, toks)
        full_logits = tf.logits_fn(params, cfg, hidden)    # (B,S,V)

        cache = tf.init_cache(cfg, b, s + 4)
        outs = []
        for t in range(s):
            logits, cache = tf.decode_step(params, cfg, toks[:, t:t + 1],
                                           cache)
            outs.append(logits)
        dec = jnp.stack(outs, 1)
        np.testing.assert_allclose(np.asarray(dec, np.float32),
                                   np.asarray(full_logits, np.float32),
                                   rtol=0.15, atol=0.15)
        # argmax agreement is the serving-level contract
        agree = np.mean(np.argmax(np.asarray(dec), -1)
                        == np.argmax(np.asarray(full_logits), -1))
        assert agree >= 0.9

    def test_prefill_matches_forward(self):
        cfg = dataclasses.replace(get_smoke_config("qwen2-1.5b"), remat=False)
        params = tf.init_params(cfg, KEY)
        toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
        hidden, _, _ = tf.forward_hidden(params, cfg, toks)
        want = tf.logits_fn(params, cfg, hidden)[:, -1]
        got, cache = tf.prefill(params, cfg, {"tokens": toks})
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)
        assert int(cache["pos"]) == 16


class TestMoE:
    def test_sort_dispatch_matches_dense_oracle(self):
        d, e, k = 64, 8, 2
        p = moe_lib.moe_init(KEY, d, e, 128, "swiglu", jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d))
        # generous capacity -> no drops -> exact match
        got, aux1 = moe_lib.moe_forward(x, p, n_experts=e, top_k=k,
                                        capacity_factor=8.0)
        want, aux2 = moe_lib.moe_dense_ref(x, p, n_experts=e, top_k=k)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
        assert float(aux1) == pytest.approx(float(aux2), rel=1e-5)

    def test_capacity_drops_bounded(self):
        d, e, k = 32, 4, 2
        p = moe_lib.moe_init(KEY, d, e, 64, "swiglu", jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(2), (1, 64, d))
        got, _ = moe_lib.moe_forward(x, p, n_experts=e, top_k=k,
                                     capacity_factor=1.0)
        want, _ = moe_lib.moe_dense_ref(x, p, n_experts=e, top_k=k)
        # drops allowed, but the layer must stay close in aggregate
        rel = (jnp.linalg.norm(got - want)
               / (jnp.linalg.norm(want) + 1e-9))
        assert float(rel) < 0.8

    def test_aux_loss_uniform_router_is_one(self):
        """Perfectly uniform routing gives aux == 1 (Switch normalisation)."""
        d, e, k = 16, 4, 1
        p = moe_lib.moe_init(KEY, d, e, 32, "swiglu", jnp.float32)
        p = dict(p, router=jnp.zeros((d, e)))     # uniform probs
        x = jax.random.normal(KEY, (1, 32, d))
        _, aux = moe_lib.moe_dense_ref(x, p, n_experts=e, top_k=k)
        assert float(aux) == pytest.approx(1.0, rel=0.3)


class TestSSD:
    def test_chunked_scan_matches_step_recurrence(self):
        b, s, h, p, n = 2, 32, 4, 8, 16
        ks = jax.random.split(KEY, 5)
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a_log = jnp.log(jnp.linspace(1, 4, h))
        bm = jax.random.normal(ks[2], (b, s, n))
        cm = jax.random.normal(ks[3], (b, s, n))
        y_chunked, h_final = ssd_lib.ssd_chunked(x, dt, a_log, bm, cm, chunk=8)

        state = jnp.zeros((b, h, p, n))
        ys = []
        for t in range(s):
            y_t, state = ssd_lib.ssd_step(x[:, t], dt[:, t], a_log,
                                          bm[:, t], cm[:, t], state)
            ys.append(y_t)
        y_step = jnp.stack(ys, 1)
        np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_step),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(h_final), np.asarray(state),
                                   rtol=2e-3, atol=2e-3)

    def test_chunk_size_invariance(self):
        b, s, h, p, n = 1, 24, 2, 4, 8
        ks = jax.random.split(jax.random.PRNGKey(9), 4)
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a_log = jnp.log(jnp.linspace(1, 2, h))
        bm = jax.random.normal(ks[2], (b, s, n))
        cm = jax.random.normal(ks[3], (b, s, n))
        y1, _ = ssd_lib.ssd_chunked(x, dt, a_log, bm, cm, chunk=4)
        y2, _ = ssd_lib.ssd_chunked(x, dt, a_log, bm, cm, chunk=12)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-3, atol=2e-3)


class TestAttention:
    def test_chunked_equals_unchunked(self):
        b, s, h, hd = 2, 40, 4, 16
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, s, h, hd))
        k = jax.random.normal(ks[1], (b, s, h, hd))
        v = jax.random.normal(ks[2], (b, s, h, hd))
        full = attention_train(q, k, v, causal=True, q_chunk=s)
        chunked = attention_train(q, k, v, causal=True, q_chunk=8)
        np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                                   rtol=1e-5, atol=1e-5)

    def test_window_mask(self):
        """With window=w, token t must ignore keys older than t-w."""
        b, s, h, hd = 1, 16, 1, 8
        ks = jax.random.split(jax.random.PRNGKey(5), 3)
        q = jax.random.normal(ks[0], (b, s, h, hd))
        k = jax.random.normal(ks[1], (b, s, h, hd))
        v = jax.random.normal(ks[2], (b, s, h, hd))
        out1 = attention_train(q, k, v, causal=True, window=4)
        # perturb key/value 10 positions before the last query
        k2 = k.at[:, 2].set(100.0)
        v2 = v.at[:, 2].set(-100.0)
        out2 = attention_train(q, k2, v2, causal=True, window=4)
        np.testing.assert_allclose(np.asarray(out1[:, -1]),
                                   np.asarray(out2[:, -1]), rtol=1e-5)

    def test_ring_cache_decode(self):
        """Ring-buffer window cache: slot wrap keeps attention correct."""
        b, kv, w, hd = 1, 2, 8, 16
        cache = KVCache(jnp.zeros((b, kv, w, hd)), jnp.zeros((b, kv, w, hd)),
                        jnp.zeros((b,), jnp.int32))
        ks = jax.random.split(KEY, 20)
        for pos in range(12):       # wraps past w=8
            k_new = jax.random.normal(ks[pos], (b, 1, kv, hd))
            v_new = jax.random.normal(ks[pos + 1], (b, 1, kv, hd))
            cache = cache_update(cache, k_new, v_new,
                                 jnp.full((b,), pos, jnp.int32), window=w)
        assert int(cache.length[0]) == w
        q = jax.random.normal(ks[19], (b, 1, kv, hd))
        out = attention_decode(q, cache, n_heads=kv)
        assert np.all(np.isfinite(np.asarray(out)))
