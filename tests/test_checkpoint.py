"""Checkpoint round-trip tests (repro.checkpoint.io).

The seed io module predates the stacked (M, .) error-feedback convention
and broke on the real qwen2_100m training state in three ways, each pinned
here: (1) python-scalar leaves (the round counter) crashed save with
``'int' object has no attribute 'dtype'``; (2) load ran blobs through
``arr.astype(tag)`` + ``jnp.asarray``, silently downcasting int64/float64
under x64-disabled jax -- not a bit-exact round-trip; (3) load validated
only the LEAF COUNT, so a same-arity but differently-shaped or
differently-structured template restored garbage instead of erroring.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.io import (latest_step, load_checkpoint, restore,
                                 save_checkpoint)
from repro.configs import get_smoke_config
from repro.launch.steps import init_ef_tree
from repro.models import transformer as tf


def _bits(x) -> np.ndarray:
    """Bit-pattern view for exact comparison (bf16 has no numpy dtype)."""
    a = np.asarray(jax.device_get(x))
    return a.view(np.uint16) if a.dtype == jnp.bfloat16 else a


def _state(n_fl: int = 4):
    cfg = get_smoke_config("qwen2-100m")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    # non-zero EF so a zeros-template can't fake a pass
    ef = jax.tree_util.tree_map(lambda x: x + 0.125,
                                init_ef_tree(params, n_fl))
    return {"params": params, "ef": ef, "round": 7}


def _like(state):
    return jax.tree_util.tree_map(
        lambda x: 0 if isinstance(x, int) else jnp.zeros_like(x), state)


class TestRoundTrip:
    def test_qwen2_100m_state_bit_exact(self, tmp_path):
        """The real thing: bf16 params + stacked (M, .) f32 EF + python-int
        round counter, back bit-for-bit with dtypes intact."""
        state = _state()
        save_checkpoint(str(tmp_path), 7, state)
        back, step = restore(str(tmp_path), _like(state))
        assert step == 7
        assert back["round"] == 7 and type(back["round"]) is int
        la = jax.tree_util.tree_leaves_with_path(state)
        lb = jax.tree_util.tree_leaves_with_path(back)
        assert len(la) == len(lb)
        for (pa, a), (pb, b) in zip(la, lb):
            assert pa == pb
            if hasattr(a, "dtype"):
                assert a.dtype == b.dtype, (pa, a.dtype, b.dtype)
            np.testing.assert_array_equal(_bits(a), _bits(b), err_msg=str(pa))

    def test_ef_dtype_variants_round_trip(self, tmp_path):
        cfg = get_smoke_config("qwen2-100m")
        params = tf.init_params(cfg, jax.random.PRNGKey(1))
        for i, dt in enumerate([jnp.float32, jnp.bfloat16]):
            ef = jax.tree_util.tree_map(
                lambda x: (x + 0.5).astype(dt), init_ef_tree(params, 2, dt))
            save_checkpoint(str(tmp_path), i, ef)
            back = load_checkpoint(
                str(tmp_path), i,
                jax.tree_util.tree_map(jnp.zeros_like, ef))
            for a, b in zip(jax.tree_util.tree_leaves(ef),
                            jax.tree_util.tree_leaves(back)):
                assert a.dtype == b.dtype
                np.testing.assert_array_equal(_bits(a), _bits(b))

    def test_latest_step_and_restore_empty(self, tmp_path):
        assert latest_step(str(tmp_path / "nowhere")) is None
        tree, step = restore(str(tmp_path), {"w": jnp.zeros(3)})
        assert tree is None and step is None
        save_checkpoint(str(tmp_path), 3, {"w": jnp.ones(3)})
        save_checkpoint(str(tmp_path), 11, {"w": jnp.full(3, 2.0)})
        assert latest_step(str(tmp_path)) == 11
        tree, step = restore(str(tmp_path), {"w": jnp.zeros(3)})
        assert step == 11 and float(tree["w"][0]) == 2.0


class TestWrongTemplateRejected:
    def test_leaf_count_mismatch(self, tmp_path):
        state = _state()
        save_checkpoint(str(tmp_path), 0, state)
        bad = {"params": _like(state)["params"]}
        with pytest.raises(AssertionError, match="leaves"):
            load_checkpoint(str(tmp_path), 0, bad)

    def test_treedef_mismatch_same_arity(self, tmp_path):
        """Same leaf count, different structure: the seed code restored
        leaves positionally into the wrong tree; now a hard error."""
        state = _state()
        save_checkpoint(str(tmp_path), 0, state)
        flat = jax.tree_util.tree_leaves(_like(state))
        bad = {f"k{i:04d}": leaf for i, leaf in enumerate(flat)}
        with pytest.raises(ValueError, match="treedef"):
            load_checkpoint(str(tmp_path), 0, bad)

    def test_shape_mismatch(self, tmp_path):
        save_checkpoint(str(tmp_path), 0, {"w": jnp.ones((4, 4))})
        with pytest.raises(ValueError, match="shape"):
            load_checkpoint(str(tmp_path), 0, {"w": jnp.zeros((4, 5))})

    def test_stacked_ef_shape_drift_detected(self, tmp_path):
        """A checkpoint written with M=4 EF rows must not restore into an
        M=8 run (the exact seed->stacked-layout migration hazard)."""
        state = _state(n_fl=4)
        save_checkpoint(str(tmp_path), 0, state)
        other = _like(_state(n_fl=8))
        with pytest.raises(ValueError, match="shape"):
            load_checkpoint(str(tmp_path), 0, other)


class TestScalarAndExoticLeaves:
    def test_python_scalars_save_and_restore(self, tmp_path):
        """Seed crash: _leaf_to_numpy assumed every leaf has .dtype."""
        tree = {"round": 3, "lr": 0.125, "w": jnp.arange(4.0)}
        save_checkpoint(str(tmp_path), 0, tree)
        back = load_checkpoint(str(tmp_path), 0,
                               {"round": 0, "lr": 0.0, "w": jnp.zeros(4)})
        assert back["round"] == 3 and type(back["round"]) is int
        assert back["lr"] == 0.125 and type(back["lr"]) is float
        np.testing.assert_array_equal(np.asarray(back["w"]),
                                      np.arange(4.0, dtype=np.float32))

    def test_int64_blob_is_not_silently_truncated(self, tmp_path):
        """Under x64-disabled jax an int64 blob cannot become a jnp array
        without downcasting; load must hand back the exact numpy array
        (or a python scalar for scalar templates), never truncated bits."""
        big = np.array([2**40 + 17, -(2**35)], dtype=np.int64)
        save_checkpoint(str(tmp_path), 0, {"steps": big, "count": 2**40})
        back = load_checkpoint(str(tmp_path), 0,
                               {"steps": np.zeros(2, np.int64), "count": 0})
        np.testing.assert_array_equal(np.asarray(back["steps"]), big)
        assert back["steps"].dtype == np.int64
        assert back["count"] == 2**40

    def test_corrupt_blob_dtype_rejected(self, tmp_path):
        path = save_checkpoint(str(tmp_path), 0, {"w": jnp.ones(3)})
        # overwrite the blob with a different dtype than the manifest tag
        np.save(os.path.join(path, "arr_00000.npy"),
                np.ones(3, dtype=np.float64))
        with pytest.raises(ValueError, match="manifest"):
            load_checkpoint(str(tmp_path), 0, {"w": jnp.zeros(3)})
