"""Yi-34B [arXiv:2403.04652] -- llama-architecture dense, GQA kv=8."""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="yi-34b", arch_type="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20_480, vocab_size=64_000,
    mlp="swiglu", norm="rmsnorm",
    fsdp=True,
    source="arXiv:2403.04652",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="yi-34b-smoke", n_layers=2, d_model=256, n_heads=8,
        n_kv_heads=2, d_ff=512, vocab_size=512, fsdp=False, remat=False,
        attn_q_chunk=64)
