"""Population-scale sweep: every EF store over a 100k-device population.

The tentpole claim of the population layer (docs/ARCHITECTURE.md §8) is
that cohort sampling makes N >= 100k devices simulable, and that the
compressed EF stores trade measured accuracy for the N x D residual
memory.  This bench runs the same sampled-cohort workload
(:func:`repro.core.population.run_population`, batched blocking, uniform
sampler) once per registered store -- "dense" (lossless reference),
"int8" (quantized residuals), "server" (one aggregate residual) -- and
records the exact EF-state footprint next to the smoke-budget
loss/accuracy, so a store whose approximation hurts convergence can't
hide.  Rows land in ``BENCH_population.json`` via ``benchmarks/run.py
--smoke`` (CI uploads it as an artifact, mirroring BENCH_tasks.json).
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core import FLConfig
from repro.core.error_feedback import EF_STORES
from repro.core.population import (make_population, make_population_task,
                                   run_population)

from .common import emit


def run(n_devices: int = 100_000, m_cohort: int = 64, rounds: int = 24,
        stores=None, emit_csv: bool = True) -> dict:
    task = make_population_task(n_shards=8, n_train=2048, seed=0)
    rows = []
    dense_bytes = None
    for store in (stores or list(EF_STORES)):
        pop = make_population(task, n_devices, ef_store=store)
        cfg = FLConfig(rounds=rounds, eval_every=max(rounds // 4, 1))
        t0 = time.time()
        hist = run_population(pop, cfg, "lgc", h=4, m_cohort=m_cohort,
                              engine="batched")
        wall = time.time() - t0
        if store == "dense":
            dense_bytes = pop.ef_nbytes
        rows.append({
            "ef_store": store, "n_devices": n_devices,
            "m_cohort": m_cohort, "rounds": rounds, "params_d": pop.d,
            "ef_bytes": pop.ef_nbytes,
            "ef_bytes_vs_dense": (round(pop.ef_nbytes / dense_bytes, 4)
                                  if dense_bytes else None),
            "wall_s": round(wall, 3),
            "final_loss": round(hist.loss[-1], 4),
            "final_accuracy": round(hist.accuracy[-1], 4),
            "uplink_mb": round(hist.uplink_mb[-1], 4),
        })
        if emit_csv:
            emit(f"population_{store}", wall * 1e6 / rounds,
                 f"ef_bytes={pop.ef_nbytes};"
                 f"acc={rows[-1]['final_accuracy']};"
                 f"loss={rows[-1]['final_loss']};n={n_devices}")
    return {"benchmark": "population", "n_devices": n_devices,
            "m_cohort": m_cohort, "rounds": rounds, "rows": rows}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-devices", type=int, default=100_000)
    ap.add_argument("--m-cohort", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=24)
    ap.add_argument("--out", default="BENCH_population.json")
    args = ap.parse_args()
    res = run(n_devices=args.n_devices, m_cohort=args.m_cohort,
              rounds=args.rounds)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
