"""Batched, device-axis-vectorized, fully-jitted LGC engine.

The reference engine in :mod:`repro.core.fl` walks a Python loop over
devices -- M jit dispatches per round plus eager compression per sync, so
simulated device count is the wall-clock bottleneck.  This engine stacks all
per-device state into leading-axis-M pytrees and compiles an entire sync
window into ONE XLA program:

    window(t0 .. te):                        # te = earliest sync / eval point
      jax.lax.scan over rounds:
        jax.vmap over devices: minibatch draw + local SGD step
      at te-1 (same program):
        jax.vmap over devices: channel sampling, layered compression
        (rank-exact or Pallas histogram backend), error feedback, QSGD,
        byte / energy / money / time accounting
        server mean of the synced devices' updates

Controller decisions happen at sync boundaries through the batched fleet
protocol (:mod:`repro.core.fl`): ONE ``act`` / ``observe`` call with (M, .)
arrays per boundary (a FleetDDPG serves it with one jitted program).  The
host loop chains windows, feeding the per-device (H_m, k_m) decision arrays
back in as *traced* values, so heterogeneous DDPG allocations never trigger
recompiles (only a new window length L does, and L takes few distinct
values).

Randomness uses the counter-based :func:`repro.core.fl.stream_key` scheme,
shared with the loop engine, so both engines simulate bit-identical
minibatches / channels / eval subsets and their History agrees to float
reduction order (verified in tests/test_fl.py::TestEngineEquivalence).
Scenario dynamics (:mod:`repro.core.scenario`) ride the same scheme: the
per-device Gauss-Markov / Gilbert-Elliott chain carry is part of the scanned
window state, advanced once per valid round from the TAG_SCEN stream, and
the realized :class:`~repro.core.channels.ChannelSample` at the sync round
reads the carry instead of fresh IID draws -- so every registry scenario
inherits the engine-equivalence invariant (tests/test_scenarios.py).

``backend="pallas"`` routes the per-device EF hot path through the fused
Pallas kernel pipeline (:func:`repro.kernels.lgc_compress_hist`: maxabs +
256-bin histogram thresholds + fused sparsify/EF), vmapped across the device
axis; ``backend="exact"`` uses the rank oracle
(:func:`repro.core.compressor.lgc_compress_traced`).

:class:`ShardedEngine` (``engine="sharded"``) partitions the leading M axis
over the FL axis of a real mesh (:func:`repro.launch.mesh.fl_axis_name`, via
the :func:`repro.launch.compat.shard_map` shim): each mesh device simulates
M/D edge devices locally -- the whole window body (local SGD scan, channel
sampling, layered compress/EF, cost accounting) runs unchanged inside the
``shard_map`` -- and only the server aggregation crosses the slow axis.
``server_reduce="gather"`` (default) all-gathers the per-device compressed
updates -- exactly the traffic LGC compresses in the paper -- and reduces the
full (M, D) matrix identically on every shard, which keeps History
BIT-identical to the unsharded engine for any shard count (the per-device
float math is batch-shape stable on XLA:CPU, and the counter-based
``stream_key`` streams are indexed by *global* device id).
``server_reduce="psum"`` crosses only the d-vector partial sums (O(d) per
link instead of O(Md/D)) at the price of a reassociated float reduction:
History then matches to ~1e-6, not bitwise.

Invariants this module carries: the equivalence ladder (tests/test_fl.py::
TestEngineEquivalence, tests/test_scenarios.py, tests/test_tasks.py --
every registry task, LR/CNN/char-RNN, and every scenario must keep
loop~batched allclose and batched==sharded bitwise) and the never-sampled
padding of :func:`_stack_device_data`
(tests/test_tasks.py::TestStackDeviceData).  The full story is
docs/ARCHITECTURE.md §2 (window anatomy) and §4 (gather-vs-psum).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .channels import comm_cost_mb, comp_cost, stack_specs
from .compressor import (flatten_tree, layer_budgets, lgc_compress_topk,
                         per_layer_candidates_hist, per_layer_compress,
                         qsgd_dequantize, qsgd_quantize, tree_layer_slices,
                         unflatten_like)
from .fl import (TAG_BATCH, TAG_CHANNEL, TAG_QUANT, History, stream_key)
from .scenario import dropout_mask, sample_from_carry, step_carry
from .server import (diloco_update, semi_sync_sums, semi_sync_update,
                     staleness_schedule)

Array = jax.Array


def _stack_device_data(device_data):
    """Pad per-device shards to a common row count and stack each batch-pytree
    leaf along a new leading device axis: (n_i, ...) -> (M, Nmax, ...).

    Shards are arbitrary pytrees of arrays sharing a leading sample axis --
    flat float features, NHWC image batches, int32 token sequences -- and
    ragged across devices.  Padding rows are zeros and must never reach the
    model: the window's minibatch gather draws indices in [0, n_i) per
    device, so only real rows are sampled
    (tests/test_tasks.py::TestStackDeviceData pins both properties)."""
    ns = [int(jax.tree_util.tree_leaves(s)[0].shape[0]) for s in device_data]
    nmax = max(ns)

    def stack(*leaves):
        out = np.zeros((len(leaves), nmax) + leaves[0].shape[1:],
                       leaves[0].dtype)
        for i, a in enumerate(leaves):
            out[i, : a.shape[0]] = a
        return jnp.asarray(out)
    data = jax.tree_util.tree_map(stack, *device_data)
    return data, jnp.asarray(ns, jnp.int32)


def make_device_phase(*, cfg, loss_fn, base, mode, backend, scenario,
                      d: int, n_ch: int):
    """Build the per-device half of the sync window as a standalone function.

    The returned ``device_phase`` runs everything in the window that is
    independent per device -- the local-SGD scan, scenario-carry stepping,
    channel sampling, layered compression + error feedback, and cost
    accounting -- on an (M_blk, ·) block of stacked state, and returns the
    masked per-device updates ``g`` *without* the server aggregation:

        device_phase(w_hat, anchor, ef, scen_carry, data, n_dev, dev_ids,
                     ts, etas, valid, sync_mask, ks_mat, *, k_cap)
          -> (w_hat', scen_carry', g_masked, ef', costs)

    The block size M_blk is whatever the leading axis of the inputs says:
    :class:`BatchedEngine` calls it with the full (M, ·) stack,
    :class:`ShardedEngine` with (M/D, ·) mesh-local blocks, and the
    population cohort engines (:mod:`repro.core.population`) with gathered
    cohort blocks down to single rows -- the per-row float math is
    batch-shape stable on XLA:CPU (docs/ARCHITECTURE.md §4, §8), which is
    what the bitwise halves of the equivalence ladder rest on.  All random
    streams are keyed by the *global* device ids in ``dev_ids``, so the
    blocking can never change the simulated trajectory.
    """
    bsz = cfg.batch_size
    vb, ib = cfg.value_bytes, cfg.index_bytes
    consts = stack_specs(cfg.channels)
    scn = scenario

    def local_round(w_hat, t, eta, valid, data, n_dev, dev_ids):
        keys = jax.vmap(lambda i: stream_key(base, TAG_BATCH, t, i))(
            dev_ids)

        def dev(w, key, n, rows):
            # gather bounded by the device's true row count n, so the
            # zero-padding rows of the stacked shards are never sampled
            idx = jax.random.randint(key, (bsz,), 0, n)
            batch = jax.tree_util.tree_map(lambda a: a[idx], rows)
            grads = jax.grad(loss_fn)(w, batch)
            # padded scan steps (valid=False) leave w bitwise untouched
            return jax.tree_util.tree_map(
                lambda p, gi: jnp.where(valid, p - eta * gi, p), w, grads)
        return jax.vmap(dev)(w_hat, keys, n_dev, data)

    def local_round_pd(w_hat, t, eta, valid_m, data, n_dev, dev_ids):
        """local_round with a PER-DEVICE (M_blk,) valid mask: the masked-step
        scan of action_space="per_device", where device m computes only the
        first h_m rounds of its window.  Same arithmetic as local_round with
        only the select predicate vmapped, so a device whose mask stays True
        takes bitwise the same steps as under the shared path."""
        keys = jax.vmap(lambda i: stream_key(base, TAG_BATCH, t, i))(
            dev_ids)

        def dev(w, key, n, rows, v):
            idx = jax.random.randint(key, (bsz,), 0, n)
            batch = jax.tree_util.tree_map(lambda a: a[idx], rows)
            grads = jax.grad(loss_fn)(w, batch)
            return jax.tree_util.tree_map(
                lambda p, gi: jnp.where(v, p - eta * gi, p), w, grads)
        return jax.vmap(dev)(w_hat, keys, n_dev, data, valid_m)

    policy = getattr(cfg, "layer_policy", "global")

    def compress(ef, delta, ks_mat, recv, k_cap, slices):
        """(g, ef_new) for all devices; layered EF, backend-dispatched.

        ``cfg.layer_policy != "global"`` prepends the per-model-layer
        candidate mask (:mod:`repro.core.compressor` per-layer section) to
        the unchanged channel layering: the policy reshapes WHICH
        coordinates compete, error feedback still accumulates u - g.  The
        "uniform" policy is bit-equal to "global" on the exact backend, so
        it rides the engine-equivalence ladder unchanged."""
        if policy != "global":
            u = ef + delta
            if backend == "pallas":
                from repro.kernels import lgc_compress_hist

                def row(ui, ki, ri):
                    b = layer_budgets(policy, ui, slices,
                                      jnp.sum(ki.astype(jnp.int32)), k_cap)
                    mask = per_layer_candidates_hist(ui, slices, b)
                    gi, _ = lgc_compress_hist(
                        jnp.zeros_like(ui), jnp.where(mask, ui, 0.0),
                        jnp.cumsum(ki), ri.astype(jnp.int32))
                    return gi
            else:
                def row(ui, ki, ri):
                    return per_layer_compress(ui, ki, ri, slices, policy,
                                              k_cap)
            g = jax.vmap(row)(u, ks_mat, recv)
            return g, u - g
        if backend == "pallas":
            from repro.kernels import lgc_compress_hist
            cum = jnp.cumsum(ks_mat, axis=1)
            return jax.vmap(
                lambda e, dl, ck, rc: lgc_compress_hist(
                    e, dl, ck, rc.astype(jnp.int32)))(
                ef, delta, cum, recv)
        u = ef + delta
        g = jax.vmap(
            lambda ui, ki, ri: lgc_compress_topk(ui, ki, ri, k_cap))(
            u, ks_mat, recv)
        return g, u - g

    def device_phase(w_hat, anchor, ef, scen_carry, data, n_dev, dev_ids,
                     ts, etas, valid, sync_mask, ks_mat, *, k_cap,
                     h_arr=None, t0=None):
        """ts/etas/valid: (L,) round indices, step sizes, padding mask
        (L is padded to a power of two so few scan programs compile);
        ks_mat: (M_blk, C); scen_carry: (M_blk, ·) scenario chain state,
        advanced one step per valid scanned round (padded steps leave it
        bitwise untouched).

        ``h_arr``/``t0`` (action_space="per_device" only): (M_blk,) local
        step counts and the replicated window start round.  Device m's SGD
        step is additionally masked to the first h_m valid rounds of the
        window (the masked-step scan) -- one program regardless of how
        heterogeneous the h_m are.  Scenario chains and channel/sync math
        are untouched: the environment evolves whether or not the device
        chooses to compute."""
        def body(state, sc):
            w, carry = state
            t, eta, v = sc
            if h_arr is None:
                w = local_round(w, t, eta, v, data, n_dev, dev_ids)
            else:
                vm = jnp.logical_and(v, (t - t0) < h_arr)
                w = local_round_pd(w, t, eta, vm, data, n_dev, dev_ids)
            carry = jax.vmap(
                lambda c, i: step_carry(scn, base, c, t, i, v))(
                carry, dev_ids)
            return (w, carry), None
        (w_hat, scen_carry), _ = jax.lax.scan(
            body, (w_hat, scen_carry), (ts, etas, valid))

        t_sync = ts[-1]
        ch_keys = jax.vmap(
            lambda i: stream_key(base, TAG_CHANNEL, t_sync, i))(dev_ids)
        ch = jax.vmap(lambda c, k: sample_from_carry(scn, consts, c, k))(
            scen_carry, ch_keys)
        if scn.has_dropout:
            drop = dropout_mask(scn, base, t_sync, dev_ids)
            ch = ch._replace(up=ch.up & ~drop[:, None])
        delta = anchor - jax.vmap(flatten_tree)(w_hat)   # (M, D)

        if mode == "fedavg":
            # dense, no error feedback; with every channel down (burst
            # outage / dropout) the upload is simply lost -- no bytes,
            # no update, and nothing carried over (FedAvg has no EF).
            # The outage mask is applied as exact where-selects AFTER
            # the unchanged cost expressions: weaving it into the float
            # chain (e.g. nbytes * any_up) lets XLA:CPU pick batch-
            # shape-dependent FMA fusions and breaks the sharded
            # bit-identity on the cost fields by ulps.
            any_up = jnp.any(ch.up, axis=1)
            g = jnp.where(any_up[:, None], delta, 0.0)
            ef_new = ef
            bw = ch.bandwidth_mb_s * ch.up
            best = jnp.argmax(bw, axis=1)
            nbytes = (jax.nn.one_hot(best, n_ch, dtype=jnp.float32)
                      * (d * vb))
            uplink_bytes = jnp.where(any_up, jnp.sum(nbytes, axis=1),
                                     0.0)
        else:
            recv = ch.up[:, :n_ch]
            # model-layer slices of the per-device flat vector, read off the
            # stacked (M_blk, ...) pytree at trace time (zero runtime cost)
            slices = tree_layer_slices(w_hat, skip_leading_axes=1)
            g, ef_new = compress(ef, delta, ks_mat, recv, k_cap, slices)
            if mode == "lgc_q8":
                kq = jax.vmap(lambda i: stream_key(
                    base, TAG_QUANT, t_sync, i))(dev_ids)
                q, scale = jax.vmap(qsgd_quantize)(g, kq)
                g_deq = jax.vmap(qsgd_dequantize)(q, scale)
                # quantization residual stays in the error memory
                ef_new = ef_new + (g - g_deq)
                g = g_deq
            vbytes = 1 if mode == "lgc_q8" else vb
            nbytes = (ks_mat.astype(jnp.float32) * (vbytes + ib)
                      * recv.astype(jnp.float32))
            uplink_bytes = jnp.sum(nbytes, axis=1)

        comm = comm_cost_mb(ch, nbytes / 1e6)            # dict of (M,)
        # byte counts are integer-valued (exact in f32 below 2^24), so the
        # host-side f64 accumulation matches the loop engine bitwise
        costs = jnp.stack([comm["energy_j"], comm["money"],
                           comm["time_s"], uplink_bytes], 1)
        costs = jnp.where(sync_mask[:, None], costs, 0.0)

        g_masked = jnp.where(sync_mask[:, None], g, 0.0)
        ef = jnp.where(sync_mask[:, None], ef_new, ef)
        return w_hat, scen_carry, g_masked, ef, costs

    return device_phase


class BatchedEngine:
    """Drives one :class:`~repro.core.fl.LGCSimulator` with stacked state.

    Host-visible simulator attributes (params, spend, decisions, next_sync,
    prev_loss) are kept in sync at window boundaries so controllers, reward
    evaluation and History recording reuse the simulator's own host-side
    code paths unchanged.
    """

    def __init__(self, sim):
        self.sim = sim
        cfg = sim.cfg
        self.m = sim.m_devices
        self.d = sim.d
        self.n_ch = len(cfg.channels)
        self.data, self.n_dev = _stack_device_data(sim.task.device_data)
        self.dev_ids = jnp.arange(self.m, dtype=jnp.int32)
        # stacked per-device state (Algorithm 1 line 1)
        self.w_hat = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (self.m,) + a.shape) + 0,
            sim.params)
        flat0 = flatten_tree(sim.params)
        self.anchor = jnp.broadcast_to(flat0[None], (self.m, self.d)) + 0
        self.ef = jnp.zeros((self.m, self.d), jnp.float32)
        # per-device scenario chain carry, stacked (M, C) -- initialized by
        # the simulator (same stationary TAG_SCEN_INIT draw the loop engine
        # starts from), advanced inside the window scan below
        self.scen_carry = sim.scen_carry
        # donate the chained per-device state (w_hat, anchor, ef,
        # scen_carry): every window consumes last window's buffers and
        # run() rebinds the attributes from the outputs, so XLA can update
        # the ~(M, D) state in place instead of allocating fresh output
        # buffers each window.  params (arg 0) is NOT donated: run() keeps
        # params_before for mid-window eval records after the call.
        # tests/test_fl.py::TestBufferDonation pins the aliasing.
        # Non-mean aggregators thread a ServerState carry as arg 5, chained
        # and donated the same way (docs/ARCHITECTURE.md §11); "mean" keeps
        # the original window signature and program byte-for-byte.
        self.server_state = sim.server_state          # None under "mean"
        donate = (1, 2, 3, 4) if sim.agg.name == "mean" else (1, 2, 3, 4, 5)
        self._window = jax.jit(self._make_window(),
                               static_argnames=("k_cap",),
                               donate_argnums=donate)

    # -- the one-XLA-program sync window ------------------------------------
    def _make_window(self, axis_name: str | None = None,
                     server_reduce: str = "gather"):
        """Build the window program: the shared device phase
        (:func:`make_device_phase`) composed with the server aggregation and
        the global-model broadcast.

        With ``axis_name`` set the returned function is a ``shard_map`` body:
        every (M, .) argument arrives as its local (M/D, .) block, ``dev_ids``
        carries the *global* device indices of the block (so the counter-based
        key streams are shard-layout independent), and the server aggregation
        crosses the mesh axis per ``server_reduce``.

        A window with an all-false sync_mask degrades to a bitwise no-op on
        params/anchor/ef with zero costs, so one program serves sync and
        record-only windows alike.
        """
        sim = self.sim
        m = self.m
        device_phase = make_device_phase(
            cfg=sim.cfg, loss_fn=sim.task.loss_fn, base=sim._base,
            mode=sim.mode, backend=sim.backend, scenario=sim.scenario,
            d=self.d, n_ch=self.n_ch)

        def _serve_mean(params, w_hat, anchor, ef, scen_carry, sync_mask,
                        g_masked, costs):
            """Server mean + broadcast, shared by the shared/per_device
            window signatures below (tracing inlines this, so the shared
            path's program is unchanged)."""
            if axis_name is None:
                g_sum = jnp.sum(g_masked, axis=0)
            elif server_reduce == "gather":
                # the per-device compressed updates -- the traffic LGC
                # compresses -- cross the slow axis; every shard then runs
                # the same (M, D) reduce as the unsharded engine, keeping
                # the server mean bit-identical for any shard count
                g_sum = jnp.sum(jax.lax.all_gather(
                    g_masked, axis_name, axis=0, tiled=True), axis=0)
            else:  # "psum": O(d) per link, float reduction is reassociated
                g_sum = jax.lax.psum(jnp.sum(g_masked, axis=0), axis_name)
            new_flat = flatten_tree(params) - g_sum / m
            new_params = unflatten_like(new_flat, params)
            m_loc = sync_mask.shape[0]          # local block under shard_map
            # broadcast: synced devices adopt the global model
            w_hat = jax.tree_util.tree_map(
                lambda wl, pl: jnp.where(
                    sync_mask.reshape((m_loc,) + (1,) * pl.ndim), pl[None],
                    wl),
                w_hat, new_params)
            anchor = jnp.where(sync_mask[:, None], new_flat[None], anchor)
            return new_params, w_hat, anchor, ef, scen_carry, costs

        def window(params, w_hat, anchor, ef, scen_carry, data,
                   n_dev, dev_ids, ts, etas, valid, sync_mask, ks_mat, *,
                   k_cap):
            w_hat, scen_carry, g_masked, ef, costs = device_phase(
                w_hat, anchor, ef, scen_carry, data, n_dev, dev_ids,
                ts, etas, valid, sync_mask, ks_mat, k_cap=k_cap)
            return _serve_mean(params, w_hat, anchor, ef, scen_carry,
                               sync_mask, g_masked, costs)

        def window_pd(params, w_hat, anchor, ef, scen_carry, data,
                      n_dev, dev_ids, ts, etas, valid, sync_mask, ks_mat,
                      h_arr, t0, *, k_cap):
            """per_device window: + (M_blk,) local-step counts and the
            replicated window start for the masked-step scan."""
            w_hat, scen_carry, g_masked, ef, costs = device_phase(
                w_hat, anchor, ef, scen_carry, data, n_dev, dev_ids,
                ts, etas, valid, sync_mask, ks_mat, k_cap=k_cap,
                h_arr=h_arr, t0=t0)
            return _serve_mean(params, w_hat, anchor, ef, scen_carry,
                               sync_mask, g_masked, costs)

        agg = sim.agg.name
        if agg == "mean":
            return window_pd if sim.per_device else window

        # -- non-mean aggregators: same device phase, a ServerState carry, --
        # -- and the repro.core.server update in place of the plain mean   --
        cfg = sim.cfg
        alpha, cap = float(cfg.staleness_alpha), int(cfg.staleness_cap)
        out_lr, out_mu = float(cfg.outer_lr), float(cfg.outer_momentum)

        def _serve_ext(params, w_hat, anchor, ef, scen_carry, server_state,
                       sync_mask, comp_time, deadline, g_masked, costs):
            T = costs[:, 2] + comp_time           # realised window seconds
            if agg == "semi_sync":
                # the fraction of each late device's update the server will
                # never apply goes straight back into its EF residual --
                # purely per-device, so shards compute it locally; gated so
                # on-time/record-only rows stay bitwise untouched
                _, _, _, undeliv = staleness_schedule(
                    T, deadline, sync_mask, alpha, cap)
                ef = jnp.where(undeliv[:, None] > 0,
                               ef + undeliv[:, None] * g_masked, ef)
            flat = flatten_tree(params)
            if agg == "diloco":
                if axis_name is None:
                    g_sum = jnp.sum(g_masked, axis=0)
                    fold = jnp.any(sync_mask)
                elif server_reduce == "gather":
                    g_sum = jnp.sum(jax.lax.all_gather(
                        g_masked, axis_name, axis=0, tiled=True), axis=0)
                    fold = jnp.any(jax.lax.all_gather(
                        sync_mask, axis_name, axis=0, tiled=True))
                else:
                    g_sum = jax.lax.psum(jnp.sum(g_masked, axis=0),
                                         axis_name)
                    fold = jax.lax.psum(
                        jnp.sum(sync_mask.astype(jnp.int32)), axis_name) > 0
                new_flat, server_state = diloco_update(
                    flat, server_state, g_sum / m, fold, out_lr, out_mu)
            else:  # semi_sync
                if axis_name is None:
                    g_now, contrib, n_sync = semi_sync_sums(
                        g_masked, T, sync_mask, deadline, alpha, cap)
                elif server_reduce == "gather":
                    gth = lambda x: jax.lax.all_gather(
                        x, axis_name, axis=0, tiled=True)
                    g_now, contrib, n_sync = semi_sync_sums(
                        gth(g_masked), gth(T), gth(sync_mask),
                        deadline, alpha, cap)
                else:  # psum: the sums are linear in devices by construction
                    g_now, contrib, n_sync = semi_sync_sums(
                        g_masked, T, sync_mask, deadline, alpha, cap)
                    g_now = jax.lax.psum(g_now, axis_name)
                    contrib = jax.lax.psum(contrib, axis_name)
                    n_sync = jax.lax.psum(n_sync, axis_name)
                new_flat, server_state = semi_sync_update(
                    flat, server_state, g_now, contrib, n_sync > 0, m)
            new_params = unflatten_like(new_flat, params)
            m_loc = sync_mask.shape[0]
            w_hat = jax.tree_util.tree_map(
                lambda wl, pl: jnp.where(
                    sync_mask.reshape((m_loc,) + (1,) * pl.ndim), pl[None],
                    wl),
                w_hat, new_params)
            anchor = jnp.where(sync_mask[:, None], new_flat[None], anchor)
            return (new_params, w_hat, anchor, ef, scen_carry, server_state,
                    costs)

        def window_ext(params, w_hat, anchor, ef, scen_carry, server_state,
                       data, n_dev, dev_ids, ts, etas, valid, sync_mask,
                       ks_mat, comp_time, deadline, *, k_cap):
            """Extended window: ``comp_time`` is the (M_blk,) f32 per-device
            compute seconds for this window's local steps, ``deadline`` the
            replicated f32 semi-sync deadline; ``server_state`` is carried
            replicated (every shard computes the identical new state)."""
            w_hat, scen_carry, g_masked, ef, costs = device_phase(
                w_hat, anchor, ef, scen_carry, data, n_dev, dev_ids,
                ts, etas, valid, sync_mask, ks_mat, k_cap=k_cap)
            return _serve_ext(params, w_hat, anchor, ef, scen_carry,
                              server_state, sync_mask, comp_time, deadline,
                              g_masked, costs)

        def window_ext_pd(params, w_hat, anchor, ef, scen_carry,
                          server_state, data, n_dev, dev_ids, ts, etas,
                          valid, sync_mask, ks_mat, comp_time, deadline,
                          h_arr, t0, *, k_cap):
            """window_ext + the per_device masked-step scan inputs."""
            w_hat, scen_carry, g_masked, ef, costs = device_phase(
                w_hat, anchor, ef, scen_carry, data, n_dev, dev_ids,
                ts, etas, valid, sync_mask, ks_mat, k_cap=k_cap,
                h_arr=h_arr, t0=t0)
            return _serve_ext(params, w_hat, anchor, ef, scen_carry,
                              server_state, sync_mask, comp_time, deadline,
                              g_masked, costs)

        return window_ext_pd if sim.per_device else window_ext

    # -- host loop: chain windows, controllers decide at boundaries ---------
    def run(self) -> History:
        sim, cfg = self.sim, self.sim.cfg
        hist = History()
        sim._decide_devices(range(self.m), 0)
        t = 0
        # cfg.pipeline_decisions: the boundary's reward eval + fresh act are
        # DEFERRED until after the next window has been dispatched, so the
        # controller's jitted programs overlap device compute instead of
        # sitting on the critical path.  The committed decisions were staged
        # one boundary earlier, so the next window's (h, ks) inputs never
        # wait on the fleet.  (sync set, reward round, params handle) --
        # params is never donated, so the boundary-time handle stays valid.
        deferred = None
        while t < cfg.rounds:
            # window boundaries are SYNC points only: global params (and
            # spend) are constant between syncs, so eval points that fall
            # mid-window are recorded afterwards against the pre-window
            # params -- identical History to the round-by-round loop
            te = min(min(sim.next_sync), cfg.rounds)
            sync_ms = [m for m in range(self.m) if sim.next_sync[m] <= te]
            length = te - t
            pad = (1 << (length - 1).bit_length()) - length
            ts = jnp.asarray(list(range(t, te)) + [te - 1] * pad, jnp.int32)
            etas = jnp.asarray(
                [sim._eta(tt) for tt in range(t, te)] + [0.0] * pad,
                jnp.float32)
            valid = jnp.asarray([True] * length + [False] * pad)
            params_before = sim.params
            extras = ((self._h_arr(), jnp.int32(t))
                      if sim.per_device else ())
            if sim.agg.name == "mean":
                deadline = None
                (sim.params, self.w_hat, self.anchor, self.ef,
                 self.scen_carry, costs) = self._window(
                    sim.params, self.w_hat, self.anchor, self.ef,
                    self.scen_carry, self.data, self.n_dev,
                    self.dev_ids, ts, etas, valid, self._sync_mask(te),
                    self._ks_mat(), *extras, k_cap=self._k_cap())
            else:
                # host-side f64 deadline from committed decisions + nominal
                # channels (identical across engines for the same sync set)
                deadline = (sim._window_deadline(sync_ms)
                            if sim.agg.uses_timing else 1.0)
                (sim.params, self.w_hat, self.anchor, self.ef,
                 self.scen_carry, self.server_state, costs) = self._window(
                    sim.params, self.w_hat, self.anchor, self.ef,
                    self.scen_carry, self.server_state, self.data,
                    self.n_dev, self.dev_ids, ts, etas, valid,
                    self._sync_mask(te), self._ks_mat(), self._comp_time(),
                    jnp.float32(deadline), *extras, k_cap=self._k_cap())
            if deferred is not None:
                ms_d, t_d, params_d = deferred
                deferred = None
                sim._observe_devices(ms_d, t_d, params=params_d)
                sim._stage_decisions(ms_d, t_d + 1)
            rec = [r for r in range(t, te)
                   if r % cfg.eval_every == 0 or r == cfg.rounds - 1]
            if rec and rec[-1] == te - 1:
                last_rec, rec = True, rec[:-1]
            else:
                last_rec = False
            if rec:
                # mid-window eval points precede this window's sync
                params_after, sim.params = sim.params, params_before
                for r in rec:
                    sim._record(hist, r)
                sim.params = params_after
            if sync_ms:
                costs_np = np.asarray(costs)
                t_wins = []
                for m in sync_ms:
                    # comp cost on host in f64, exactly like the loop engine
                    ccomp = comp_cost(sim.profiles[m], sim.decisions[m].h)
                    s = sim.spend[m]
                    s["energy_j"] += float(costs_np[m, 0]) + ccomp["energy_j"]
                    s["money"] += float(costs_np[m, 1]) + ccomp["money"]
                    s["time_s"] += float(costs_np[m, 2]) + ccomp["time_s"]
                    s["mb"] += float(costs_np[m, 3]) / 1e6
                    t_wins.append(float(costs_np[m, 2]) + ccomp["time_s"])
                # simulated server wall-clock (f64, from the same costs_np
                # both sharded and unsharded runs see bitwise): sync servers
                # wait for the slowest uplink, semi_sync for the deadline
                if sim.agg.uses_timing:
                    sim.server_wall_s += min(deadline, max(t_wins))
                else:
                    sim.server_wall_s += max(t_wins)
                sim._update_chan_state(self.scen_carry)
                if cfg.pipeline_decisions:
                    # commit now (the next window's inputs); evaluate the
                    # reward and stage the boundary-after-next's decisions
                    # once that window is in flight.  Same fleet-call order
                    # as the loop engine (observe, then act) -- only the
                    # host-side bookkeeping moves.
                    sim._commit_staged(sync_ms, te)
                    deferred = (sync_ms, te - 1, sim.params)
                else:
                    sim._observe_devices(sync_ms, te - 1)
                    sim._decide_devices(sync_ms, te)
            if last_rec:
                sim._record(hist, te - 1)
            t = te
        if deferred is not None:
            # final boundary: nothing left to overlap with -- flush so the
            # fleet sees the same observe/act sequence as the loop engine
            ms_d, t_d, params_d = deferred
            sim._observe_devices(ms_d, t_d, params=params_d)
            sim._stage_decisions(ms_d, t_d + 1)
        return hist

    def _sync_mask(self, te: int) -> Array:
        return jnp.asarray([s <= te for s in self.sim.next_sync])

    def _comp_time(self) -> Array:
        """(M,) f32 compute seconds of each device's committed window (the
        straggler-adjusted profile x local steps) -- the compute half of the
        semi-sync staleness input, f32 like the in-window comm time."""
        sim = self.sim
        return jnp.asarray(
            [np.float32(comp_cost(sim.profiles[m],
                                  sim.decisions[m].h)["time_s"])
             for m in range(self.m)], jnp.float32)

    def _k_cap(self) -> int:
        """Static top-k bound for the threshold-based layer selection,
        rounded to a power of two AND monotone across the run: the
        threshold selection is cap-invariant for any cap >= cumsum(ks)
        (``rank_below`` reads only ``vals[b-1]`` for the budget boundaries
        b), so reusing the largest cap seen keeps the results bitwise
        identical while eliminating the recompile that used to fire every
        time a DDPG budget change crossed a power of two in *either*
        direction (tests/test_fl.py::TestBufferDonation pins one-program
        behaviour)."""
        if self.sim.mode == "fedavg":
            return 1                      # unused by the dense path
        k_max = max(1, max(sum(dec.ks) for dec in self.sim.decisions))
        cap = min(self.d, 1 << (k_max - 1).bit_length())
        self._k_cap_hi = max(cap, getattr(self, "_k_cap_hi", 0))
        return self._k_cap_hi

    def _h_arr(self) -> Array:
        """(M,) committed local-step counts as a traced array (per_device
        windows only) -- heterogeneous h_m never recompiles the window."""
        return jnp.asarray([dec.h for dec in self.sim.decisions], jnp.int32)

    def _ks_mat(self) -> Array:
        """Per-device layer budgets as a traced (M, C) array (topk folds all
        budget into channel 0; rows are padded/trimmed to the channel count)."""
        rows = []
        for dec in self.sim.decisions:
            ks = list(dec.ks)
            if self.sim.mode == "topk":
                ks = [sum(ks)] + [0] * (len(ks) - 1)
            ks = (ks + [0] * self.n_ch)[: self.n_ch]
            rows.append(ks)
        return jnp.asarray(rows, jnp.int32)


class ShardedEngine(BatchedEngine):
    """Batched engine with the device axis partitioned over a real mesh.

    The (M, .) pytrees are sharded over the mesh's FL axis
    (:func:`repro.launch.mesh.fl_axis_name`): each of the D mesh devices owns
    an M/D block of edge devices and runs the whole window program --
    sync-window SGD scan, channel sampling, layered compress/EF, cost
    accounting -- on its block, inside one :func:`repro.launch.compat.shard_map`
    body.  Only the server aggregation crosses the slow axis (see
    ``server_reduce`` in :meth:`BatchedEngine._make_window`); with the
    default ``"gather"`` reduce, History is bit-identical to the unsharded
    :class:`BatchedEngine` (tests/test_sharded.py).

    Host-side control (windows, controller boundaries, History recording)
    is exactly the base class's ``run``: only ``_window`` is replaced by a
    per-``k_cap`` cache of jitted shard_map programs, and the stacked state
    is pre-placed so window outputs stay sharded across window boundaries.
    """

    def __init__(self, sim, mesh=None, server_reduce: str = "gather"):
        from repro.launch.compat import shardings
        from repro.launch.mesh import fl_axis_name, make_host_mesh

        if server_reduce not in ("gather", "psum"):
            raise ValueError(f"unknown server_reduce: {server_reduce!r}")
        self.mesh = mesh if mesh is not None else make_host_mesh()
        self.axis = fl_axis_name(self.mesh)
        self.n_shards = int(self.mesh.shape[self.axis])
        self.server_reduce = server_reduce
        m = sim.m_devices
        if m % self.n_shards != 0:
            raise ValueError(
                f"ShardedEngine: M={m} simulated devices do not divide over "
                f"{self.n_shards} mesh devices on axis {self.axis!r}; pick "
                f"M a multiple of the FL axis size")
        super().__init__(sim)

        from jax.sharding import PartitionSpec as P
        shard, rep = P(self.axis), P()
        if sim.agg.name == "mean":
            # args: params, w_hat, anchor, ef, scen_carry, data (a batch
            #       pytree -- the single spec applies leaf-wise as a
            #       prefix), n_dev, dev_ids, ts, etas, valid, sync_mask,
            #       ks_mat
            in_specs = [rep, shard, shard, shard, shard, shard,
                        shard, shard, rep, rep, rep, shard, shard]
            self._out_specs = (rep, shard, shard, shard, shard, shard)
        else:
            # extended window: + the replicated ServerState carry after
            # scen_carry, and the sharded (M,) comp_time + replicated
            # deadline scalar at the tail (see _make_window's window_ext)
            in_specs = [rep, shard, shard, shard, shard, rep, shard,
                        shard, shard, rep, rep, rep, shard, shard,
                        shard, rep]
            self._out_specs = (rep, shard, shard, shard, shard, rep, shard)
        if sim.per_device:
            # the masked-step scan's (M,) h_arr shards with the device
            # axis; the t0 window-start scalar is replicated
            in_specs += [shard, rep]
        self._in_specs = tuple(in_specs)
        # pre-place the stacked state and data so every window call reuses
        # the resident shards instead of re-scattering from host
        place = lambda tree: jax.device_put(
            tree, shardings(self.mesh, shard))
        self.data = place(self.data)
        self.n_dev, self.dev_ids = place(self.n_dev), place(self.dev_ids)
        self.w_hat = place(self.w_hat)
        self.anchor, self.ef = place(self.anchor), place(self.ef)
        self.scen_carry = place(self.scen_carry)
        if self.server_state is not None:
            self.server_state = jax.device_put(
                self.server_state, shardings(self.mesh, rep))
        self._donate = ((1, 2, 3, 4) if sim.agg.name == "mean"
                        else (1, 2, 3, 4, 5))
        self._programs: dict[int, Callable] = {}
        self._window = self._dispatch_window

    def _dispatch_window(self, *args, k_cap: int):
        fn = self._programs.get(k_cap)
        if fn is None:
            from repro.launch.compat import shard_map
            body = functools.partial(
                self._make_window(self.axis, self.server_reduce),
                k_cap=k_cap)
            fn = jax.jit(shard_map(
                body, mesh=self.mesh, in_specs=self._in_specs,
                out_specs=self._out_specs),
                # same donation contract as the unsharded window: the
                # chained (M, .) state updates in place, shard-resident
                # (+ the ServerState carry under non-mean aggregators)
                donate_argnums=self._donate)
            self._programs[k_cap] = fn
        return fn(*args)
