"""End-to-end driver: train a ~100M-parameter qwen2-family model for a few
hundred steps with LGC gradient compression across 8 simulated FL devices.

This is the real training path (actual arrays, actual shard_map step --
the same code the dry-run lowers for the production mesh), running on 8
host devices.  Loss must decrease; the script also reports the LGC wire
savings vs a dense exchange.

  PYTHONPATH=src python examples/train_100m_lgc.py [--steps 300]
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.launch import sharding_rules as rules
from repro.launch import compat
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import (LGCStepConfig, init_ef_tree,
                                make_lgc_train_step)
from repro.models import transformer as tf


def hundred_m_config():
    """qwen2-family scaled to ~100M params."""
    base = get_config("qwen2-1.5b")
    return dataclasses.replace(
        base, name="qwen2-100m", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=2, d_ff=2048, vocab_size=32_000, tie_embeddings=True,
        remat=False, attn_q_chunk=128, loss_chunk=256)


def main():
    ap = argparse.ArgumentParser()
    # defaults sized for the 1-core CPU container; on a real pod raise all
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--local-steps", type=int, default=2)
    args = ap.parse_args()

    cfg = hundred_m_config()
    mesh = make_host_mesh(8, model=1)       # 8 FL devices on the data axis
    compat.set_mesh(mesh)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params, 8 FL devices, "
          f"H={args.local_steps} local steps, sparsity 1%+2%+2%")

    lgc = LGCStepConfig(local_steps=args.local_steps, local_lr=3e-3,
                        sparsity=(0.01, 0.02, 0.02))
    pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch, seed=0)
    x0, y0 = pipe.next_batch()
    batch0 = {"tokens": jnp.asarray(x0), "labels": jnp.asarray(y0)}
    bspecs = rules.batch_specs(cfg, batch0, mesh)
    pspecs = rules.param_specs(cfg, params, mesh)
    params = rules.place(params, pspecs, mesh)
    step = jax.jit(make_lgc_train_step(cfg, mesh, lgc, bspecs),
                   in_shardings=compat.shardings(mesh, (pspecs, pspecs, bspecs)),
                   donate_argnums=(0, 1))
    ef = rules.place(init_ef_tree(params), pspecs, mesh)

    t0, losses = time.time(), []
    for i in range(args.steps):
        x, y = pipe.next_batch()
        params, ef, loss = step(params, ef,
                                {"tokens": jnp.asarray(x),
                                 "labels": jnp.asarray(y)})
        losses.append(float(loss))
        if i % 20 == 0 or i == args.steps - 1:
            print(f"round {i:4d} loss {losses[-1]:.4f} "
                  f"({time.time()-t0:.0f}s)")

    dense_mb = n * 4 / 1e6
    lgc_mb = n * sum(lgc.sparsity) * 8 / 1e6   # (val+idx) per selected coord
    print(f"\nwire per round per device: dense {dense_mb:.1f} MB vs "
          f"LGC {lgc_mb:.1f} MB  ({dense_mb/lgc_mb:.1f}x reduction)")
    if args.steps >= 20:
        assert losses[-1] < losses[0], "loss must decrease"
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {args.steps} rounds")


if __name__ == "__main__":
    main()
