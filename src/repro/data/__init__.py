"""Data pipelines: synthetic MNIST, embedded Shakespeare, LM token streams,
and federated partitioners (IID, label-subset, Dirichlet, quantity skew).

Every loader/partitioner here is deterministic per ``seed`` and produces the
per-device shards behind the task zoo
(:data:`repro.models.paper_models.TASKS`); partition invariants (exact
partitions, non-empty devices, alpha skew direction) are pinned by
tests/test_scenarios.py::TestPartitionerProperties, and the Shakespeare
train/eval split disjointness by tests/test_tasks.py."""
from .mnist import load_synthetic_mnist, partition_iid, partition_noniid
from .partition import (label_marginals, partition_dirichlet,
                        partition_quantity_skew, shard_for_device,
                        skew_score)
from .shakespeare import (CHAR_VOCAB, VOCAB_SIZE, char_batches, char_shards,
                          char_windows, load_shakespeare, split_stream)
from .tokens import TokenPipeline, synthetic_token_batch

__all__ = [
    "load_synthetic_mnist", "partition_iid", "partition_noniid",
    "label_marginals", "partition_dirichlet", "partition_quantity_skew",
    "shard_for_device", "skew_score",
    "CHAR_VOCAB", "VOCAB_SIZE", "char_batches", "char_shards",
    "char_windows", "load_shakespeare", "split_stream",
    "TokenPipeline", "synthetic_token_batch",
]
