"""Paper Table 1: channel energy model -- sampled means must match spec."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.channels import DEFAULT_CHANNELS, sample_channels
from .common import emit


def run(n: int = 2000, emit_csv: bool = True) -> dict:
    keys = jax.random.split(jax.random.PRNGKey(0), n)
    t0 = time.time()
    samples = [sample_channels(k) for k in keys[:50]]
    dt = (time.time() - t0) / 50
    energy = np.stack([np.asarray(s.energy_j_per_mb) for s in samples])
    out = {}
    for i, spec in enumerate(DEFAULT_CHANNELS):
        mean = float(energy[:, i].mean())
        out[spec.name] = {"mean_j_per_mb": mean,
                          "spec": spec.energy_mean_j_per_mb}
        if emit_csv:
            emit(f"table1_{spec.name}", dt * 1e6,
                 f"mean={mean:.2f};spec={spec.energy_mean_j_per_mb:.2f}")
    return out


if __name__ == "__main__":
    run()
