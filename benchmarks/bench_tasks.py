"""Task zoo sweep: throughput + smoke-budget accuracy for every engine
task (repro.models.paper_models.ENGINE_TASKS) on the batched engine
(qwen2_100m is not an FLTask; its frontier lives in bench_100m.py).

The perf trajectory (BENCH_sim.json, BENCH_sharded.json) has so far only
ever measured ``lr_mnist``; the paper's evaluation (§4.1) spans LR, CNN and
a char-RNN.  This bench runs each registry task end-to-end under the fixed
LGC controller and records the final loss/accuracy next to
``device_steps_per_s`` -- the *steady-state* window throughput, measured
with the compile-excluding chained-window pattern shared with
``bench_sharded_scaling`` -- so a kernel or engine change that only helps
flat float models can't hide (``wall_s`` keeps the end-to-end time,
compile included, for reference).  Rows land in ``BENCH_tasks.json`` via
``benchmarks/run.py --smoke`` (CI uploads it as an artifact).

``--profile`` switches to profiling mode: instead of the sweep it compiles
one task's window program, writes the analysis/hlo_cost breakdown, the
compile memory/aliasing stats (buffer donation visible as aliased output
bytes) and a steady-window timing to a text artifact (CI uploads it from
the bench-smoke lane; docs/ARCHITECTURE.md §10 reads one).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (FLConfig, FixedController, LGCSimulator,
                        run_baseline, tree_size)
from repro.core.compressor import (LAYER_POLICIES, flatten_tree,
                                   layer_budgets, per_layer_wire_bytes,
                                   tree_layer_slices, wire_bytes)
from repro.core.fl_batched import BatchedEngine
from repro.models.paper_models import ENGINE_TASKS, make_task

from .bench_sharded_scaling import _steady_window_rate
from .common import emit


# per-task shape knobs: keep every task inside the smoke budget while still
# doing enough optimisation steps for the accuracy column to mean something
_TASK_KW = {
    "lr_mnist": dict(n_train=2000),
    "cnn_mnist": dict(n_train=1200),
    "rnn_shakespeare": dict(n_train=2000, seq=32),
}

# the fixed steady-state traffic allocation (one layer per default channel)
_STEADY_KS = [200, 300, 400]


def _policy_wire_bytes(task, ks, cfg) -> dict:
    """Bytes-on-wire of one sync upload per layer policy.

    "global" is the flat sparse format (4-byte global indices); the
    per-layer policies pay layer-local indices (ceil(log2(layer_size))
    rounded up to bytes -- repro.core.compressor.per_layer_wire_bytes).
    Budgets for the data-dependent policies come from a real update proxy:
    one minibatch gradient at init on device 0's shard."""
    params = task.init(jax.random.PRNGKey(0))
    slices = tree_layer_slices(params)
    d = tree_size(params)
    batch = jax.tree_util.tree_map(lambda a: a[:64], task.device_data[0])
    u = flatten_tree(jax.grad(task.loss_fn)(params, batch))
    k_total = min(int(sum(ks)), d)
    out = {"global": sum(wire_bytes(ks, cfg.value_bytes, cfg.index_bytes))}
    for pol in sorted(LAYER_POLICIES):
        b = layer_budgets(pol, u, slices, jnp.int32(k_total), d)
        out[pol] = per_layer_wire_bytes(
            [int(x) for x in np.asarray(b)], slices, cfg.value_bytes)
    return out


def run(tasks=None, m: int = 8, rounds: int = 40, batch_size: int = 32,
        emit_csv: bool = True) -> dict:
    # the FLTask zoo only: qwen2_100m is not an engine task (its frontier
    # is bench_100m.py), so default to ENGINE_TASKS, not the full registry
    names = list(tasks or ENGINE_TASKS)
    rows = []
    for name in names:
        task = make_task(name, m_devices=m, **_TASK_KW.get(name, {}))
        d = tree_size(task.init(jax.random.PRNGKey(0)))
        cfg = FLConfig(rounds=rounds, eval_every=max(rounds // 4, 1),
                       batch_size=batch_size)
        t0 = time.time()
        hist = run_baseline(task, cfg, "lgc", h=4, engine="batched")
        wall = time.time() - t0
        # steady-state throughput: chain windows of one compiled program and
        # time everything after the first call (compile excluded), same
        # methodology as bench_sharded_scaling
        sim = LGCSimulator(task, cfg,
                           [FixedController(4, _STEADY_KS)] * m,
                           mode="lgc", engine="batched")
        eng = BatchedEngine(sim)
        rate, _ = _steady_window_rate(sim, eng, m, h=4,
                                      k_windows=max(rounds // 4, 4))
        rows.append({
            "task": name, "engine": "batched", "m_devices": m,
            "rounds": rounds, "params_d": d, "wall_s": round(wall, 3),
            "device_steps_per_s": round(rate, 1),
            "final_loss": round(hist.loss[-1], 4),
            "final_accuracy": round(hist.accuracy[-1], 4),
            "uplink_mb": round(hist.uplink_mb[-1], 4),
            # one sync upload's bytes on the wire, per layer policy (the
            # per-layer formats pay layer-local indices; same k_total)
            "wire_bytes_per_policy": _policy_wire_bytes(task, _STEADY_KS,
                                                        cfg),
        })
        if emit_csv:
            emit(f"task_{name}", wall * 1e6 / rounds,
                 f"device_steps_per_s={rows[-1]['device_steps_per_s']};"
                 f"acc={rows[-1]['final_accuracy']};"
                 f"loss={rows[-1]['final_loss']};d={d}")
    return {"benchmark": "tasks", "m_devices": m, "rounds": rounds,
            "rows": rows}


def profile(task_name: str = "cnn_mnist", m: int = 8, h: int = 4,
            k_windows: int = 8, out: str | None = None) -> str:
    """Profile one task's compiled window program; returns the report text.

    Three sections, in the order a perf investigation reads them:

    1. compile stats -- ``memory_analysis()`` including the output bytes
       aliased to donated inputs (the buffer-donation satellite's receipt);
    2. analysis/hlo_cost breakdown of the optimized HLO, top ops by
       flops+bytes (what the program *should* cost);
    3. steady-window timing with process CPU utilization (what it *does*
       cost -- util well below 1.0 on a busy program means the runtime, not
       the math, is the bottleneck; that signature is how the 740x scan
       pathology in docs/ARCHITECTURE.md §10 was found).
    """
    from repro.analysis.hlo_cost import breakdown_hlo

    task = make_task(task_name, m_devices=m, **_TASK_KW.get(task_name, {}))
    cfg = FLConfig(rounds=4 * k_windows, eval_every=k_windows)
    sim = LGCSimulator(task, cfg, [FixedController(h, _STEADY_KS)] * m,
                       mode="lgc", engine="batched")
    eng = BatchedEngine(sim)
    sim._decide_devices(range(m), 0)
    k_cap = eng._k_cap()
    ts = jnp.arange(h, dtype=jnp.int32)
    etas = jnp.asarray([sim._eta(t) for t in range(h)], jnp.float32)
    ones = jnp.ones((h,), bool)
    lowered = eng._window.lower(
        sim.params, eng.w_hat, eng.anchor, eng.ef, eng.scen_carry,
        eng.data, eng.n_dev, eng.dev_ids, ts, etas, ones,
        jnp.ones((m,), bool), eng._ks_mat(), k_cap=k_cap)
    compiled = lowered.compile()
    lines = [f"window profile: task={task_name} m={m} h={h} "
             f"d={sim.d} k_cap={k_cap}",
             f"XLA_FLAGS={os.environ.get('XLA_FLAGS', '')!r}", ""]

    lines.append("-- compile stats (donated-input aliasing) --")
    mem = compiled.memory_analysis()
    for attr in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "temp_size_in_bytes"):
        val = getattr(mem, attr, None)
        if val is not None:
            lines.append(f"  {attr}: {val}")
    lines.append("")

    lines.append("-- hlo_cost breakdown (optimized HLO, top 20 op_names) --")
    for op_name, cost in breakdown_hlo(compiled.as_text(), top=20):
        lines.append(f"  {op_name:<40} flops={cost.flops:.3e} "
                     f"bytes={cost.bytes:.3e}")
    lines.append("")

    lines.append("-- steady-window timing --")
    rate, util = _steady_window_rate(sim, eng, m, h, k_windows)
    lines.append(f"  device_steps_per_s: {rate:.1f}")
    lines.append(f"  cpu_util: {util:.2f}")
    report = "\n".join(lines) + "\n"
    if out:
        with open(out, "w") as f:
            f.write(report)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--m", type=int, default=8)
    ap.add_argument("--tasks", default=None,
                    help="comma-separated registry names (default: all)")
    ap.add_argument("--out", default="BENCH_tasks.json")
    ap.add_argument("--profile", metavar="TASK", default=None,
                    help="profile one task's window program instead of "
                         "sweeping; writes a text report to --out "
                         "(default PROFILE_<task>.txt)")
    args = ap.parse_args()
    if args.profile:
        out = (args.out if args.out != "BENCH_tasks.json"
               else f"PROFILE_{args.profile}.txt")
        print(profile(args.profile, m=args.m, out=out), end="")
        print(f"profile written to {out}")
        return
    names = args.tasks.split(",") if args.tasks else None
    res = run(tasks=names, m=args.m, rounds=args.rounds)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
