"""Model zoo: paper models (LR/CNN/RNN) + assigned architecture backbones."""
