"""FL simulator (Algorithm 1) behaviour: convergence, baselines, resources,
async gaps, controller integration, and the Theorem-1 bound sanity checks."""
import jax
import numpy as np
import pytest

from repro.core import (FLConfig, FixedController, FleetDDPG, LGCSimulator,
                        ProblemConstants, corollary1_rate, make_fleet_ddpg,
                        run_baseline, theorem1_bound, tree_size)
from repro.core.controller import (DDPGConfig, DDPGController, ReplayBuffer,
                                   decode_actions)
from repro.core.fl import TAG_REWARD
from repro.models.paper_models import make_mnist_task, make_shakespeare_task

from _hypothesis_compat import given, settings, st  # hypothesis or fallback


@pytest.fixture(scope="module")
def lr_task():
    return make_mnist_task("lr", m_devices=3, n_train=1200)


class TestAlgorithm1:
    def test_lgc_converges(self, lr_task):
        cfg = FLConfig(rounds=80, eval_every=20)
        h = run_baseline(lr_task, cfg, "lgc", h=4)
        assert h.loss[-1] < h.loss[0] - 0.2
        assert h.accuracy[-1] > 0.4

    def test_lgc_tracks_fedavg_loss(self, lr_task):
        cfg = FLConfig(rounds=80, eval_every=40)
        h_lgc = run_baseline(lr_task, cfg, "lgc", h=4)
        h_avg = run_baseline(lr_task, cfg, "fedavg", h=4)
        # paper claim: similar convergence despite ~20x less uplink
        assert h_lgc.loss[-1] < h_avg.loss[-1] + 0.35

    def test_lgc_saves_energy_and_money(self, lr_task):
        cfg = FLConfig(rounds=60, eval_every=30)
        h_lgc = run_baseline(lr_task, cfg, "lgc", h=4)
        h_avg = run_baseline(lr_task, cfg, "fedavg", h=4)
        assert h_lgc.energy_j[-1] < 0.5 * h_avg.energy_j[-1]
        assert h_lgc.money[-1] < 0.5 * h_avg.money[-1]
        assert h_lgc.uplink_mb[-1] < 0.25 * h_avg.uplink_mb[-1]

    def test_topk_single_channel_baseline_runs(self, lr_task):
        cfg = FLConfig(rounds=30, eval_every=15)
        h = run_baseline(lr_task, cfg, "topk", h=4)
        assert h.loss[-1] < h.loss[0]

    def test_async_gaps_respected(self, lr_task):
        """Devices with different H sync at different times; gap <= max_gap."""
        cfg = FLConfig(rounds=40, eval_every=20, max_gap=6)
        ctrls = [FixedController(h, [200, 300, 400]) for h in (2, 3, 6)]
        sim = LGCSimulator(lr_task, cfg, ctrls, mode="lgc")
        sim.run()
        for m, c in enumerate(ctrls):
            assert sim.decisions[m].h <= cfg.max_gap

    def test_rnn_task_runs(self):
        task = make_shakespeare_task(m_devices=2, seq=24)
        cfg = FLConfig(rounds=12, eval_every=6, batch_size=16)
        h = run_baseline(task, cfg, "lgc", h=3)
        assert np.isfinite(h.loss[-1])


class TestEngineEquivalence:
    """The batched (vmap+scan, one-XLA-program-per-window) engine must
    reproduce the reference loop engine's trajectory: both draw from the
    same counter-based key streams, so History agrees to float reduction
    order."""

    @pytest.mark.parametrize("mode", ["lgc", "fedavg", "topk", "lgc_q8"])
    def test_history_matches_loop(self, lr_task, mode):
        cfg = FLConfig(rounds=30, eval_every=10)
        h_loop = run_baseline(lr_task, cfg, mode, h=4, engine="loop")
        h_bat = run_baseline(lr_task, cfg, mode, h=4, engine="batched")
        assert h_loop.step == h_bat.step
        np.testing.assert_allclose(h_bat.loss, h_loop.loss, atol=1e-4)
        np.testing.assert_allclose(h_bat.accuracy, h_loop.accuracy, atol=1e-4)
        np.testing.assert_allclose(h_bat.uplink_mb, h_loop.uplink_mb,
                                   atol=1e-4)
        np.testing.assert_allclose(h_bat.energy_j, h_loop.energy_j, rtol=1e-5)
        np.testing.assert_allclose(h_bat.time_s, h_loop.time_s, rtol=1e-5)

    def test_heterogeneous_gaps_match(self, lr_task):
        """Devices with different H sync at different rounds; the chunked
        scan must hit exactly the same sync set as the loop."""
        cfg = FLConfig(rounds=25, eval_every=8, max_gap=6)
        hists = {}
        for engine in ("loop", "batched"):
            ctrls = [FixedController(h, [200, 300, 400]) for h in (2, 3, 6)]
            sim = LGCSimulator(lr_task, cfg, ctrls, mode="lgc", engine=engine)
            hists[engine] = sim.run()
            assert all(d.h <= cfg.max_gap for d in sim.decisions)
        np.testing.assert_allclose(hists["batched"].loss, hists["loop"].loss,
                                   atol=1e-4)
        np.testing.assert_allclose(hists["batched"].uplink_mb,
                                   hists["loop"].uplink_mb, atol=1e-4)

    def test_pallas_backend_matches_loop_and_learns(self, lr_task):
        """backend='pallas' (histogram thresholds + fused EF kernel) is an
        approximation of the exact rank oracle, but both engines must agree
        with each other on it, and it must still converge."""
        cfg = FLConfig(rounds=20, eval_every=10)
        h_loop = run_baseline(lr_task, cfg, "lgc", h=4,
                              engine="loop", backend="pallas")
        h_bat = run_baseline(lr_task, cfg, "lgc", h=4,
                             engine="batched", backend="pallas")
        np.testing.assert_allclose(h_bat.loss, h_loop.loss, atol=1e-4)
        assert h_bat.loss[-1] < h_bat.loss[0]

    def test_batched_is_default_engine(self):
        assert FLConfig().engine == "batched"

    def test_sharded_matches_batched(self):
        """engine="sharded" (shard_map over the host mesh's FL axis, gather
        server reduce) reproduces the unsharded batched engine's History
        BIT-identically -- on however many host devices are present (the
        test-sharded CI lane forces an 8-way host mesh; the plain lane runs
        the same check on a 1-way mesh).  M=8 divides every power-of-two
        shard count."""
        task = make_mnist_task("lr", m_devices=8, n_train=2000)
        cfg = FLConfig(rounds=30, eval_every=10)
        h_bat = run_baseline(task, cfg, "lgc", h=4, engine="batched")
        h_sh = run_baseline(task, cfg, "lgc", h=4, engine="sharded")
        assert h_sh.asdict() == h_bat.asdict()

    @pytest.mark.parametrize("engine", ["loop", "batched"])
    def test_fleet_matches_agent_list(self, lr_task, engine):
        """FleetDDPG(M) and the legacy per-device agent list (through the
        ControllerFleet shim) share counter-based stream_key randomness AND
        the same compiled per-device programs, so a fixed seed drives them
        to bit-identical decisions and History -- training engaged."""
        d = tree_size(lr_task.init(jax.random.PRNGKey(0)))
        k_total = max(3, d // 20)

        def controllers(kind):
            if kind == "list":
                return [DDPGController(DDPGConfig(
                    k_total_max=k_total, batch_size=4, seed=5 + 17 * m))
                    for m in range(3)]
            return FleetDDPG(3, DDPGConfig(
                k_total_max=k_total, batch_size=4, seed=5))

        runs = {}
        for kind in ("list", "fleet"):
            cfg = FLConfig(rounds=40, eval_every=10)
            sim = LGCSimulator(lr_task, cfg, controllers(kind), mode="lgc",
                               engine=engine)
            hist = sim.run()
            trains = (sim.fleet._n_train.copy() if kind == "fleet" else
                      np.array([c._fleet._n_train[0]
                                for c in sim.controllers]))
            runs[kind] = (sim.decision_log, hist.asdict(), trains)
        assert runs["fleet"][2].sum() > 0           # DDPG actually trained
        assert runs["fleet"][0] == runs["list"][0]  # bit-identical decisions
        assert runs["fleet"][1] == runs["list"][1]  # identical History
        np.testing.assert_array_equal(runs["fleet"][2], runs["list"][2])

    def test_fleet_m32_batched_smoke(self):
        """An M=32 fleet on the batched engine: one jitted controller call
        per boundary, decisions within the H / budget bounds, finite loss."""
        task = make_mnist_task("lr", m_devices=32, n_train=2000)
        d = tree_size(task.init(jax.random.PRNGKey(0)))
        fleet = make_fleet_ddpg(32, d)
        cfg = FLConfig(rounds=12, eval_every=6)
        sim = LGCSimulator(task, cfg, fleet, mode="lgc", engine="batched")
        h = sim.run()
        assert np.isfinite(h.loss[-1])
        k_total = fleet.cfg.k_total_max
        assert {m for _, m, _, _ in sim.decision_log} == set(range(32))
        for _, _, hh, ks in sim.decision_log:
            assert 1 <= hh <= cfg.max_gap
            assert sum(ks) <= k_total and min(ks) >= 1
        # a single probe state broadcasts to all 32 learned policies
        hs, kss = fleet.allocation(np.array([1e3, 0.01, 10, 1], np.float32))
        assert hs.shape == (32,) and kss.shape == (32, 3)


class TestBatchedRewardEval:
    """The batched TAG_REWARD eval (one jitted lax.map program per sync
    boundary, rows padded to a power of two) must match the old per-device
    ``_eval_subset(TAG_REWARD, (t, m), 512)`` host loop bit-for-bit, for any
    subset of devices and any round -- it feeds the DDPG reward, where ulp
    drift would desynchronize the fleet-vs-list bit-identity invariant."""

    _sim = None

    @classmethod
    def sim(cls):
        # cached plain helper, not a pytest fixture: @given composes with
        # both real hypothesis and the offline fallback shim this way
        if cls._sim is None:
            task = make_mnist_task("lr", m_devices=6, n_train=1500)
            ctrls = [FixedController(4, [200, 300, 400]) for _ in range(6)]
            cls._sim = LGCSimulator(task, FLConfig(rounds=10), ctrls,
                                    mode="lgc")
        return cls._sim

    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 63), st.integers(0, 10_000))
    def test_matches_per_device_loop(self, subset_bits, t):
        sim = self.sim()
        ms = [m for m in range(6) if subset_bits & (1 << m)]
        batched = sim._reward_losses(ms, t)
        reference = [sim._eval_subset(TAG_REWARD, (t, m), 512)[0] for m in ms]
        assert batched == reference          # float equality, bit-for-bit


class TestTheoremBounds:
    CONSTS = ProblemConstants(mu=0.5, l_smooth=4.0, g2=25.0, sigma2=4.0,
                              b=64, m=3, gamma=0.05, h=4, w0_dist2=10.0)

    def test_bound_positive_and_decreasing_in_t(self):
        b1 = theorem1_bound(self.CONSTS, 500)
        b2 = theorem1_bound(self.CONSTS, 5000)
        assert b1 > b2 > 0

    def test_bound_increases_with_gap(self):
        import dataclasses
        loose = dataclasses.replace(self.CONSTS, h=16)
        assert theorem1_bound(loose, 1000) > theorem1_bound(self.CONSTS, 1000)

    def test_corollary_rate_order(self):
        r1 = corollary1_rate(self.CONSTS, 1000)
        r2 = corollary1_rate(self.CONSTS, 10_000)
        assert r1 > r2 > 0
        # leading term is O(1/T): a 10x budget cuts the rate by ~10x
        assert r1 / r2 > 5


class TestDDPG:
    def test_replay_buffer_ring(self):
        buf = ReplayBuffer(8, 4, 3)
        for i in range(12):
            buf.add(np.full(4, i), np.zeros(3), float(i), np.zeros(4))
        assert buf.n == 8
        rng = np.random.default_rng(0)
        s, a, r, s2 = buf.sample(rng, 16)
        assert s.shape == (16, 4) and r.min() >= 4  # oldest overwritten

    def test_action_ranges(self):
        c = DDPGController(DDPGConfig(h_max=8, k_total_max=1000, n_channels=3))
        for _ in range(5):
            d = c.act(np.array([1.0, 2.0, 3.0, 4.0], np.float32))
            assert 1 <= d.h <= 8
            assert len(d.ks) == 3
            assert all(k >= 1 for k in d.ks)
            assert sum(d.ks) <= 1000    # decoded budgets never overshoot

    def test_decode_never_overshoots_budget(self):
        """Rounding the >=1 floors used to let sum(ks) exceed k_total_max;
        the decoder now shaves the largest layers back to the budget."""
        rng = np.random.RandomState(0)
        for k_total in (3, 7, 100, 1000):
            a = np.clip(rng.randn(256, 4) * 2, -1, 1).astype(np.float32)
            h, ks = decode_actions(a, 8, k_total, 3)
            assert ks.min() >= 1
            assert (ks.sum(-1) <= max(3, k_total)).all()
            assert ((1 <= h) & (h <= 8)).all()
        # adversarial: one channel hoards the budget, others round up to 1
        a = np.array([0.0, 1.0, -1.0, -1.0], np.float32)
        _, ks = decode_actions(a, 8, 10, 3)
        assert ks.sum() <= 10 and ks.min() >= 1

    def test_allocation_is_greedy_and_stream_free(self):
        """allocation() exposes the learned policy without consuming the
        exploration stream: interleaving it does not change act()."""
        mk = lambda: DDPGController(DDPGConfig(k_total_max=500, seed=9))
        probe = np.array([10.0, 0.1, 5.0, 1.0], np.float32)
        c1, c2 = mk(), mk()
        seq1 = []
        for _ in range(4):
            seq1.append(c1.act(probe))
        seq2 = []
        for _ in range(4):
            c2.allocation(probe)            # must not advance any stream
            seq2.append(c2.act(probe))
        assert [(d.h, tuple(d.ks)) for d in seq1] == \
            [(d.h, tuple(d.ks)) for d in seq2]
        # greedy decode is deterministic
        d1, d2 = c1.allocation(probe), c1.allocation(probe)
        assert (d1.h, tuple(d1.ks)) == (d2.h, tuple(d2.ks))

    def test_learning_updates_weights(self):
        cfg = DDPGConfig(batch_size=8, buffer_size=64, seed=1)
        c = DDPGController(cfg)
        w0 = np.asarray(c.actor[0]["w"]).copy()
        s = np.ones(4, np.float32) * 2.0   # nonzero state: first-layer grads flow
        for i in range(20):
            c.act(s * (i + 1))
            c.reward(0.1, s * (i + 2))
        assert len(c.critic_losses) > 0
        assert not np.allclose(w0, np.asarray(c.actor[0]["w"]))

    def test_reward_sign_follows_loss_drop(self):
        c = DDPGController(DDPGConfig(seed=2))
        s = np.ones(4, np.float32)
        c.act(s)
        c.reward(0.5, s * 2)
        c.act(s)
        c.reward(-0.5, s * 4)
        assert c.rewards[0] > 0 > c.rewards[1]


class TestBufferDonation:
    """The chained-window programs donate their per-device state buffers
    (w_hat, anchor, ef, scen_carry) so each window updates ~(M, D) state in
    place -- referenced by the donate_argnums comments in
    repro.core.fl_batched / repro.core.population."""

    def _engine(self, m=4):
        from repro.core.fl_batched import BatchedEngine
        task = make_mnist_task("lr", m_devices=m, n_train=200)
        cfg = FLConfig(rounds=8, eval_every=4, batch_size=8)
        sim = LGCSimulator(task, cfg, [FixedController(2, [50, 80])] * m,
                           mode="lgc", engine="batched")
        return sim, BatchedEngine(sim)

    def _lower(self, sim, eng, k_cap):
        import jax.numpy as jnp
        h = 2
        ts = jnp.arange(h, dtype=jnp.int32)
        etas = jnp.asarray([sim._eta(t) for t in range(h)], jnp.float32)
        return eng._window.lower(
            sim.params, eng.w_hat, eng.anchor, eng.ef, eng.scen_carry,
            eng.data, eng.n_dev, eng.dev_ids, ts, etas,
            jnp.ones((h,), bool), jnp.ones((eng.m,), bool), eng._ks_mat(),
            k_cap=k_cap)

    def test_state_buffers_aliased_params_not(self):
        sim, eng = self._engine()
        sim._decide_devices(range(eng.m), 0)
        lowered = self._lower(sim, eng, eng._k_cap())
        hlo = lowered.as_text()
        # donated inputs surface as aliased outputs in the stablehlo text
        assert "tf.aliasing_output" in hlo
        mem = lowered.compile().memory_analysis()
        alias = getattr(mem, "alias_size_in_bytes", None)
        if alias is not None:                 # plugin-dependent attribute
            # at least the three (M, D) f32 stacks alias in place; params
            # (arg 0) must NOT be donated -- run() reads params_before
            # after the window call
            assert alias >= 3 * eng.m * eng.d * 4
            assert alias < getattr(mem, "output_size_in_bytes", 2 ** 62)

    def test_run_still_correct_after_donation(self):
        """Donation must not change semantics: full engine run works and
        matches the loop engine (the ladder's allclose rung)."""
        sim, eng = self._engine()
        hist = eng.run()
        task = make_mnist_task("lr", m_devices=4, n_train=200)
        cfg = FLConfig(rounds=8, eval_every=4, batch_size=8)
        sim_l = LGCSimulator(task, cfg,
                             [FixedController(2, [50, 80])] * 4,
                             mode="lgc", engine="loop")
        hist_l = sim_l.run()
        np.testing.assert_allclose(hist.loss, hist_l.loss, rtol=2e-4)

    def test_k_cap_monotone_no_recompile_downward(self):
        """_k_cap never shrinks: after seeing a large budget the engine
        reuses the bigger program for smaller budgets (selection is
        k_cap-invariant), avoiding recompiles when DDPG shrinks ks."""
        sim, eng = self._engine()
        sim._decide_devices(range(eng.m), 0)
        big = eng._k_cap()
        for m_ in range(eng.m):
            sim.decisions[m_] = type(sim.decisions[m_])(2, [10, 20])
        assert eng._k_cap() == big            # no downward recompile
