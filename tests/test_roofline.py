"""Roofline machinery: trip-count-aware HLO cost model vs analytic ground
truth, collective-byte parsing, and model_flops accounting."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(py: str) -> str:
    """Run a snippet in a subprocess with its own XLA device count (keeps
    this test module independent of the session's device configuration)."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(py)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


class TestHloCostModel:
    def test_scan_trip_count_exact(self):
        out = _run("""
            import jax, jax.numpy as jnp
            from repro.analysis.hlo_cost import analyze_hlo
            def f(x, ws):
                c, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)
                return c.sum()
            x = jax.ShapeDtypeStruct((256,128), jnp.float32)
            ws = jax.ShapeDtypeStruct((7,128,128), jnp.float32)
            c = analyze_hlo(jax.jit(f).lower(x, ws).compile().as_text())
            print(c.flops / (2*256*128*128*7))
        """)
        assert abs(float(out.strip()) - 1.0) < 0.02

    def test_nested_scan(self):
        out = _run("""
            import jax, jax.numpy as jnp
            from repro.analysis.hlo_cost import analyze_hlo
            def inner(c, w):
                return jnp.tanh(c @ w), None
            def outer(c, ws):
                c2, _ = jax.lax.scan(inner, c, ws)
                return c2, None
            def f(x, ws):
                c, _ = jax.lax.scan(outer, x, ws)
                return c.sum()
            x = jax.ShapeDtypeStruct((64,64), jnp.float32)
            ws = jax.ShapeDtypeStruct((3,5,64,64), jnp.float32)
            c = analyze_hlo(jax.jit(f).lower(x, ws).compile().as_text())
            print(c.flops / (2*64*64*64*15))
        """)
        assert abs(float(out.strip()) - 1.0) < 0.05

    def test_sharded_flops_per_device_and_collectives(self):
        out = _run("""
            import jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            from repro.analysis.hlo_cost import analyze_hlo
            from repro.launch import compat
            mesh = compat.make_mesh((8,), ("data",))
            compat.set_mesh(mesh)
            def f(x, w):
                return jnp.sum(x @ w)
            x = jax.ShapeDtypeStruct((512, 256), jnp.float32)
            w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
            comp = jax.jit(f, in_shardings=compat.shardings(mesh, (P('data', None), P(None, None))),
                           out_shardings=compat.shardings(mesh, P())).lower(x, w).compile()
            c = analyze_hlo(comp.as_text())
            print(c.flops / (2*512*256*256/8), sum(c.coll.values()) >= 4)
        """)
        ratio, has_coll = out.split()
        assert abs(float(ratio) - 1.0) < 0.05
        assert has_coll == "True"

    def test_collective_parse_kinds(self):
        from repro.analysis.hlo_cost import HloCostModel
        hlo = """
HloModule m

ENTRY %main (p: f32[64,4]) -> f32[64,4] {
  %p = f32[64,4]{1,0} parameter(0)
  %ag = f32[512,4]{1,0} all-gather(%p), replica_groups={}, dimensions={0}
  %ar = f32[64,4]{1,0} all-reduce(%p), to_apply=%add
  ROOT %cp = f32[64,4]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
        c = HloCostModel(hlo).total()
        assert c.coll["all-gather"] == 512 * 4 * 4
        assert c.coll["all-reduce"] == 64 * 4 * 4
        assert c.coll["collective-permute"] == 64 * 4 * 4


class TestModelFlops:
    def test_dense_6nd(self):
        from repro.analysis.roofline import model_flops
        from repro.configs import get_config
        cfg = get_config("qwen2-1.5b")
        n = cfg.param_count()
        assert model_flops(cfg, "train", 1000) == pytest.approx(6 * n * 1000)
        assert model_flops(cfg, "decode", 10) == pytest.approx(2 * n * 10)

    def test_moe_uses_active_params(self):
        from repro.analysis.roofline import model_flops
        from repro.configs import get_config
        cfg = get_config("olmoe-1b-7b")
        assert cfg.active_param_count() < 0.25 * cfg.param_count()
        assert model_flops(cfg, "train", 100) == pytest.approx(
            6 * cfg.active_param_count() * 100)

    def test_param_counts_near_nameplate(self):
        from repro.configs import get_config
        expect = {"glm4-9b": 9.4e9, "yi-34b": 34.4e9, "qwen2-1.5b": 1.5e9,
                  "mamba2-370m": 0.42e9, "starcoder2-7b": 7.4e9,
                  "grok-1-314b": 314e9, "olmoe-1b-7b": 6.9e9,
                  "zamba2-1.2b": 1.2e9, "whisper-small": 0.28e9,
                  "phi-3-vision-4.2b": 3.8e9}
        for a, n in expect.items():
            got = get_config(a).param_count()
            assert abs(got - n) / n < 0.12, (a, got, n)
