"""Server aggregation modes: the sync mean, DiLoCo, and semi-sync staleness.

Every engine used to hard-code one server: wait for every syncing device,
average, subtract.  Under ``gilbert_flaky`` bursts and straggler compute
multipliers that sync barrier makes the *slowest* uplink set simulated
wall-clock -- exactly the dynamic-environment cost LGC is meant to absorb
(ROADMAP item 3).  This module is the registry of server aggregation modes
(:data:`AGGREGATORS`) plus the pure jnp math each engine splices in at its
server-update site:

* ``mean`` -- today's path and the default.  The engines keep their original
  inline code when ``cfg.aggregator == "mean"``, so the documented identity
  rung -- ``aggregator="mean"`` AND ``staleness_cap=0`` is BITWISE equal to
  the pre-server-subsystem ladder -- holds by construction
  (tests/test_server.py::TestMeanIdentityRung pins it).

* ``diloco`` -- DiLoCo-style outer optimisation (SNIPPETS.md snippet 2,
  maxtext diloco.py; Douillard et al. 2023): devices still run their H
  inner SGD steps and upload compressed net deltas, but the server treats
  the cohort-averaged delta as an *outer gradient* and applies a Nesterov
  momentum step (:func:`diloco_update`) with ``cfg.outer_lr`` /
  ``cfg.outer_momentum``.  At ``outer_lr=1, outer_momentum=0`` the update
  degenerates to the plain mean (pinned in tests).

* ``semi_sync`` -- bounded-staleness semi-synchronous aggregation.  Each
  sync window gets an uplink deadline derived from the scenario's channel /
  compute state (:func:`window_deadline`: ``cfg.deadline_factor`` x the
  median *nominal* window time of the syncing devices -- straggler compute
  multipliers and nominal channel bandwidths both enter).  A device whose
  realised window time T (comm time from the realised channel draw + local
  compute time) exceeds the deadline is *late* by ``s = ceil(T/deadline)-1``
  windows: its update misses this round, is buffered in the server-side
  staleness ring (:class:`ServerState`.stale), and folds into the
  aggregate ``s`` windows later scaled by the staleness weight

      w(s) = 1 / (1 + s) ** cfg.staleness_alpha

  up to ``cfg.staleness_cap`` windows.  Updates later than the cap are
  dropped server-side.  The undelivered fraction -- ``1 - w(s)`` for
  buffered updates, all of it for dropped ones -- is added back into the
  device's error-feedback residual (building on the PR-4 dropout+EF
  semantics: no update mass is ever silently lost,
  tests/test_server.py::TestSemiSync).

The math is split into *linear-in-devices partial sums*
(:func:`semi_sync_sums`) and a *state update* (:func:`semi_sync_update`,
:func:`diloco_update`) so the sharded engine can choose its collective:
``server_reduce="gather"`` computes the sums on the all-gathered (M, D)
matrices -- identical floats to the unsharded engine, keeping the
batched==sharded rung bitwise -- while ``"psum"`` psums the (d,) /
(cap, d) partials.  The staleness ring is part of the window-carried
:class:`ServerState`, threaded through the chained window calls exactly
like the scenario carry (replicated across shards).

Simulated wall-clock (History.server_wall_s): the sync servers advance it
by ``max_m T_m`` per window (slowest-uplink semantics) while ``semi_sync``
advances it by ``min(deadline, max_m T_m)`` -- the server never waits past
the deadline.  benchmarks/bench_async.py publishes the comparison per
scenario into BENCH_async.json and benchmarks/check_regression.py gates it.

The contract this module relaxes bit-identity into is documented in
docs/ARCHITECTURE.md §11; tests/test_server.py enforces it (identity rung
bitwise, diloco/semi_sync loop~batched allclose + batched==sharded bitwise
in gather mode, convergence floors under the scenario zoo).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AggregatorSpec:
    """One server aggregation mode (an :data:`AGGREGATORS` entry)."""
    name: str
    uses_timing: bool       # window needs per-device times + a deadline
    carries_state: bool     # window threads a ServerState carry
    doc: str


AGGREGATORS: dict[str, AggregatorSpec] = {
    "mean": AggregatorSpec(
        "mean", uses_timing=False, carries_state=False,
        doc="synchronous cohort mean (the default; bitwise-identical to the "
            "pre-server-subsystem engines)"),
    "diloco": AggregatorSpec(
        "diloco", uses_timing=False, carries_state=True,
        doc="H inner SGD steps per device, Nesterov-momentum outer step on "
            "the averaged net delta (outer_lr / outer_momentum)"),
    "semi_sync": AggregatorSpec(
        "semi_sync", uses_timing=True, carries_state=True,
        doc="per-window uplink deadline; late updates fold s windows later "
            "with weight 1/(1+s)^alpha up to staleness_cap, EF carrying the "
            "undelivered mass"),
}


def get_aggregator(name: str) -> AggregatorSpec:
    """Resolve a registry name, raising on unknown aggregators."""
    try:
        return AGGREGATORS[name]
    except KeyError:
        raise ValueError(
            f"unknown aggregator {name!r}; registered: "
            f"{sorted(AGGREGATORS)}") from None


class ServerState(NamedTuple):
    """Server-side optimiser state threaded across sync windows.

    ``momentum`` is the DiLoCo outer Nesterov momentum (zeros under other
    aggregators); ``stale`` is the semi-sync staleness ring: row ``j`` holds
    the weighted update mass that folds into the aggregate ``j + 1``
    server rounds from now (shape ``(staleness_cap, d)``; a zero cap gives
    an empty ring and every late update is dropped to EF).  Replicated
    across shards -- every shard computes the identical new state.
    """
    momentum: Array     # (d,) f32
    stale: Array        # (cap, d) f32


def init_server_state(cfg, d: int) -> ServerState:
    """Zero state sized for ``cfg`` (cap rows only under semi_sync)."""
    cap = int(cfg.staleness_cap) if cfg.aggregator == "semi_sync" else 0
    if cfg.staleness_cap < 0:
        raise ValueError(f"staleness_cap must be >= 0, got "
                         f"{cfg.staleness_cap}")
    return ServerState(momentum=jnp.zeros((d,), jnp.float32),
                       stale=jnp.zeros((cap, d), jnp.float32))


# ---------------------------------------------------------------------------
# deadline (host-side, engine-shared): nominal window time of the cohort
# ---------------------------------------------------------------------------

def nominal_uplink_s(cfg, mode: str, ks: Sequence[int], d: int) -> float:
    """Nominal (spec-bandwidth, all-channels-up) uplink seconds for one
    device's committed budgets -- layers travel in parallel, so the max
    across channels, mirroring :func:`repro.core.channels.comm_cost`."""
    bws = [c.bandwidth_mb_s for c in cfg.channels]
    if mode == "fedavg":
        return d * cfg.value_bytes / 1e6 / max(bws)
    ks = list(ks)
    if mode == "topk":
        ks = [sum(ks)] + [0] * (len(ks) - 1)
    vb = 1 if mode == "lgc_q8" else cfg.value_bytes
    return max(k * (vb + cfg.index_bytes) / 1e6 / bw
               for k, bw in zip(ks, bws))


def window_deadline(cfg, mode: str, d: int, items) -> float:
    """The semi-sync uplink deadline for one window, from the scenario's
    channel/compute state: ``cfg.deadline_factor`` x the median nominal
    window time (compute + nominal uplink) over the syncing devices.

    ``items`` is ``[(h, ks, profile), ...]`` for the syncing cohort --
    committed decisions plus the (straggler-adjusted) compute profiles.
    Host-side f64 and a deterministic median, so every engine derives the
    identical deadline for the identical sync set."""
    times = [p.comp_time_per_step_s * h + nominal_uplink_s(cfg, mode, ks, d)
             for h, ks, p in items]
    return max(float(cfg.deadline_factor) * float(np.median(times)), 1e-9)


# ---------------------------------------------------------------------------
# pure jnp server math (traced inside the window programs)
# ---------------------------------------------------------------------------

def staleness_schedule(T: Array, deadline: Array, mask: Array,
                       alpha: float, cap: int):
    """Per-device staleness bookkeeping for one window.

    Returns ``(s, w, on_time, undelivered)``: lateness in windows
    ``s = max(ceil(T/deadline) - 1, 0)`` (f32-valued integers), the fold
    weight ``w(s) = 1/(1+s)^alpha``, the on-time mask, and the fraction of
    each device's update the server will never apply (0 on time,
    ``1 - w(s)`` while buffered, 1 past the cap) -- the EF add-back.
    Purely per-device, so shards evaluate it locally."""
    dl = jnp.maximum(deadline, 1e-9)
    s = jnp.maximum(jnp.ceil(T / dl) - 1.0, 0.0)
    s = jnp.where(mask, s, 0.0)
    on = mask & (s == 0.0)
    w = 1.0 / (1.0 + s) ** alpha
    undeliv = jnp.where(on | ~mask, 0.0,
                        jnp.where(s <= cap, 1.0 - w, 1.0))
    return s, w, on, undeliv


def semi_sync_sums(g: Array, T: Array, mask: Array, deadline: Array,
                   alpha: float, cap: int):
    """Linear-in-devices partial sums of the semi-sync fold.

    ``g``: (M_blk, d) masked updates; returns ``(g_now, contrib, n_sync)``
    -- the on-time aggregate (d,), the staleness-ring deposits (cap, d)
    (row j gets ``w(j+1) * g`` of the devices exactly j+1 windows late),
    and the synced-device count.  All three are sums over the device axis,
    so the psum reduce can combine shard-local partials; the gather reduce
    calls this once on the full gathered matrices instead, reproducing the
    unsharded floats exactly."""
    s, w, on, _ = staleness_schedule(T, deadline, mask, alpha, cap)
    g_now = jnp.sum(jnp.where(on[:, None], g, 0.0), axis=0)
    sel = mask & (s >= 1.0) & (s <= cap)
    wsel = jnp.where(sel, w, 0.0)
    onehot = jax.nn.one_hot(s.astype(jnp.int32) - 1, cap, dtype=g.dtype)
    contrib = (onehot * wsel[:, None]).T @ g
    n_sync = jnp.sum(mask.astype(jnp.int32))
    return g_now, contrib, n_sync


def semi_sync_update(flat: Array, state: ServerState, g_now: Array,
                     contrib: Array, fold: Array, m_total: int):
    """Apply one semi-sync server round to the flat global model.

    Folds the maturing ring row into the on-time aggregate, shifts the ring
    and deposits this window's late contributions, and subtracts the
    cohort-normalised aggregate.  ``fold`` gates everything: a window where
    no device syncs must leave params and the ring bitwise untouched (the
    batched engine's record-only windows have no loop-engine counterpart).
    """
    cap = state.stale.shape[0]
    if cap:
        g_apply = g_now + state.stale[0]
        shifted = jnp.concatenate(
            [state.stale[1:], jnp.zeros_like(state.stale[:1])], axis=0)
        state = state._replace(
            stale=jnp.where(fold, shifted + contrib, state.stale))
    else:
        g_apply = g_now
    new_flat = flat - jnp.where(fold, g_apply, jnp.zeros_like(g_apply)) \
        / m_total
    return new_flat, state


def diloco_update(flat: Array, state: ServerState, delta: Array,
                  fold: Array, outer_lr: float, outer_mu: float):
    """One Nesterov-momentum outer step on the averaged net delta.

    The maxtext diloco.py idiom: the cohort-averaged parameter delta is the
    outer gradient; ``m' = mu m + delta``, ``params -= lr (delta + mu m')``.
    With ``outer_lr=1, outer_mu=0`` this is exactly the plain mean
    (``0 * m'`` is an exact zero), which tests pin.  ``fold`` gates the
    no-sync windows like :func:`semi_sync_update`."""
    mom_new = outer_mu * state.momentum + delta
    step = outer_lr * (delta + outer_mu * mom_new)
    new_flat = flat - jnp.where(fold, step, jnp.zeros_like(step))
    return new_flat, state._replace(
        momentum=jnp.where(fold, mom_new, state.momentum))
