"""Multi-channel communication model (paper §4.1, Table 1).

Each edge device connects to the server over N heterogeneous channels
(3G / 4G / 5G by default).  Per channel we model:

* energy per MB  -- Gaussian, Table 1:  3G mean 1296 J/MB, 4G 2.2x, 5G
  2.5*2.2x, sigma 0.00033 (paper adopts (Wang et al. 2019)'s model);
* bandwidth MB/s -- lognormal-jittered around a nominal rate (the paper calls
  the network "highly dynamic"; it does not publish rates, we use public
  nominal figures: 3G ~0.6 MB/s, 4G ~3 MB/s, 5G ~25 MB/s);
* money cost per MB -- flat tariff per technology (5G most expensive);
* availability  -- Bernoulli per round (a dropped channel loses its layer,
  the layered code degrades gracefully).

All sampling is numpy-free, driven by jax.random keys, so simulations are
fully reproducible.

Invariants: the memoryless sampler here is the "static" scenario's exact
semantics (tests/test_scenarios.py::
test_static_scenario_bitwise_matches_seed_model) and the cost model must
price identically in both engines (tests/test_substrate.py::TestChannels;
byte counts stay integer-valued so f32 accounting is exact below 2^24 --
docs/ARCHITECTURE.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

Array = jax.Array

_3G_MEAN_J_PER_MB = 1296.0  # Table 1


@dataclasses.dataclass(frozen=True)
class ChannelSpec:
    name: str
    energy_mean_j_per_mb: float           # Table 1 mean
    energy_std: float                     # Table 1 standard deviation
    bandwidth_mb_s: float                 # nominal throughput
    money_per_mb: float                   # tariff
    availability: float = 1.0             # P(channel up in a round)


DEFAULT_CHANNELS: tuple[ChannelSpec, ...] = (
    ChannelSpec("3G", _3G_MEAN_J_PER_MB, 0.00033, 0.6, 0.01, 0.98),
    ChannelSpec("4G", 2.2 * _3G_MEAN_J_PER_MB, 0.00033, 3.0, 0.02, 0.95),
    ChannelSpec("5G", 2.5 * 2.2 * _3G_MEAN_J_PER_MB, 0.00033, 25.0, 0.05, 0.90),
)


class ChannelSample(NamedTuple):
    """Realised channel conditions for one device in one round.

    A NamedTuple (registered pytree) so it can flow through jit / vmap /
    scan -- the batched simulator samples all M devices' channels inside
    one XLA program.
    """
    energy_j_per_mb: Array      # (N,)
    bandwidth_mb_s: Array       # (N,)
    money_per_mb: Array         # (N,)
    up: Array                   # (N,) bool


class ChannelConstants(NamedTuple):
    """Per-channel spec constants stacked into arrays (for jitted sampling)."""
    energy_mean: Array          # (N,)
    energy_std: Array           # (N,)
    bw_nominal: Array           # (N,)
    money_per_mb: Array         # (N,)
    availability: Array         # (N,)


def stack_specs(specs: Sequence[ChannelSpec] = DEFAULT_CHANNELS
                ) -> ChannelConstants:
    return ChannelConstants(
        energy_mean=jnp.array([s.energy_mean_j_per_mb for s in specs]),
        energy_std=jnp.array([s.energy_std for s in specs]),
        bw_nominal=jnp.array([s.bandwidth_mb_s for s in specs]),
        money_per_mb=jnp.array([s.money_per_mb for s in specs]),
        availability=jnp.array([s.availability for s in specs]))


def sample_channels_from(key: Array, consts: ChannelConstants) -> ChannelSample:
    """Core sampling math against pre-stacked constants (jit/vmap friendly)."""
    n = consts.energy_mean.shape[0]
    k_e, k_b, k_u = jax.random.split(key, 3)
    energy = consts.energy_mean + consts.energy_std * jax.random.normal(k_e, (n,))
    # lognormal jitter, sigma=0.3 -- "highly dynamic edge network"
    bw = consts.bw_nominal * jnp.exp(0.3 * jax.random.normal(k_b, (n,)))
    up = jax.random.uniform(k_u, (n,)) < consts.availability
    return ChannelSample(energy, bw, consts.money_per_mb, up)


def sample_channels(key: Array, specs: Sequence[ChannelSpec] = DEFAULT_CHANNELS,
                    ) -> ChannelSample:
    return sample_channels_from(key, stack_specs(specs))


def comm_cost(sample: ChannelSample, bytes_per_channel: Sequence[int]
              ) -> dict[str, Array]:
    """Energy (J), money, and transfer time (s) for one upload.

    Layers travel in parallel on their channels, so wall time is the max
    across channels; energy/money are sums.  Dropped channels transmit
    nothing (their layer is lost for this round).
    """
    # f32 byte counts divided in f32, matching the batched engine's in-program
    # accounting bit-for-bit (counts are integer-valued, exact below 2^24)
    mb = jnp.asarray(bytes_per_channel, jnp.float32) / 1e6
    return comm_cost_mb(sample, mb)


def comm_cost_mb(sample: ChannelSample, mb: Array) -> dict[str, Array]:
    """:func:`comm_cost` on MB arrays; batches over leading axes under vmap."""
    mb = jnp.where(sample.up, mb, 0.0)
    energy = jnp.sum(mb * sample.energy_j_per_mb, -1)
    money = jnp.sum(mb * sample.money_per_mb, -1)
    time_s = jnp.max(jnp.where(sample.up, mb / sample.bandwidth_mb_s, 0.0), -1)
    return {"energy_j": energy, "money": money, "time_s": time_s}


# Per-local-step compute energy model (J per SGD step per MFLOP); the paper's
# E_comp is device-specific -- we expose it as a constant per device profile.
@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    name: str = "generic-phone"
    comp_j_per_step: float = 0.75   # J per local SGD step (model-size scaled)
    comp_time_per_step_s: float = 0.05
    # state of charge in [0, 1]; static per run (a device trait, like the
    # compute multiplier).  The heterogeneous controller observes it and
    # decode_actions clamps h_m to 1 + floor(battery * (h_max - 1)), so a
    # zero-battery device never computes more than the one mandatory step
    # (tests/test_controller_actions.py).
    battery: float = 1.0


def comp_cost(profile: DeviceProfile, h_steps: int) -> dict[str, float]:
    return {
        "energy_j": profile.comp_j_per_step * h_steps,
        "money": 0.0,
        "time_s": profile.comp_time_per_step_s * h_steps,
    }
