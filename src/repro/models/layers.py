"""Transformer primitives: norms, RoPE, GQA attention (train / prefill /
decode / sliding-window), MLPs.  Pure functions over param pytrees.

Conventions:
  * activations  (B, S, D); attention heads  (B, S, H, hd)
  * params are dicts of jnp arrays; layer-stacked params carry a leading L dim
  * math in cfg.dtype (bf16), softmax/norm statistics in f32
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
NEG_INF = -1e30


def maybe_constrain(x: Array, *spec_axes) -> Array:
    """with_sharding_constraint iff a mesh with a 'model' axis is live.

    Keeps model code mesh-agnostic: under the production meshes the
    constraint pins GSPMD's layout choice; in plain CPU tests it is a no-op.
    """
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and "model" in getattr(am, "axis_names", ()):
            from jax.sharding import PartitionSpec as P
            return jax.lax.with_sharding_constraint(x, P(*spec_axes))
    except Exception:  # noqa: BLE001 -- no mesh context
        pass
    return x


def mesh_axis_size(name: str) -> int:
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and name in getattr(am, "axis_names", ()):
            return dict(zip(am.axis_names, am.axis_sizes))[name]
    except Exception:  # noqa: BLE001
        pass
    return 1


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, -1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layernorm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return out.astype(x.dtype) * scale + bias


def apply_norm(x: Array, p: dict, kind: str) -> Array:
    if kind == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, jnp.float32) / hd))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, hd); positions: (B, S) or (S,) absolute positions."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: Array        # (B, n_kv, S_cache, hd)
    v: Array        # (B, n_kv, S_cache, hd)
    length: Array   # (B,) number of valid positions (ring buffer aware)


def _split_heads(x: Array, n: int, hd: int) -> Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd)


def qkv_project(x: Array, p: dict, n_heads: int, n_kv: int, hd: int,
                bias: bool) -> tuple[Array, Array, Array]:
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (_split_heads(q, n_heads, hd), _split_heads(k, n_kv, hd),
            _split_heads(v, n_kv, hd))


def _expand_kv(k: Array, n_heads: int) -> Array:
    """(B,S,Kv,hd) -> (B,S,H,hd) by repeating each kv head H/Kv times."""
    b, s, kv, hd = k.shape
    rep = n_heads // kv
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, rep, hd)
                            ).reshape(b, s, n_heads, hd)


def attention_train(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int = 0, q_chunk: int = 512,
                    remat_chunks: bool = True,
                    seq_shard: bool = True) -> Array:
    """Query-chunked masked attention.

    q: (B,S,H,hd); k,v: (B,S,H,hd) (kv already expanded to H heads).
    Chunking the query axis keeps the logits transient at
    (B, H, q_chunk, S) instead of (B, H, S, S) -- the XLA analogue of flash
    attention's memory behaviour (DESIGN.md; the Pallas kernel target is
    repro.kernels.swa_attention for the decode path).

    Perf iterations (EXPERIMENTS.md §Perf):
      * remat_chunks: rematerialise each chunk in the backward pass instead
        of stashing the (B,H,qc,Sk) probability tensors per chunk per layer
        (I-B1: the stacked probs dominated HBM traffic at S=4096).
      * seq_shard: pin K/V to a sequence-sharded layout over the ``model``
        axis (context-parallel attention).  Head counts that do not divide
        the axis (yi-34b: 56 heads / 16) otherwise force GSPMD to replicate
        whole activations every layer (I-B2).
    """
    b, s, h, hd = q.shape
    s_k = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    qc = min(q_chunk, s)
    n_chunks = (s + qc - 1) // qc
    pad = n_chunks * qc - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qh = q.reshape(b, n_chunks, qc, h, hd)
    kh = jnp.swapaxes(k, 1, 2)      # (B,H,Sk,hd)
    vh = jnp.swapaxes(v, 1, 2)
    # I-B3 (EXPERIMENTS.md §Perf): seq-sharding K/V helps exactly when the
    # head count does NOT divide the model axis (yi-34b 56H, qwen2 12H --
    # GSPMD would otherwise replicate whole activations); when heads DO
    # divide (glm4 32H), the default head-sharded layout is already optimal
    # and forcing seq-shard quadrupled the collective term.
    if seq_shard and s_k % 128 == 0 and h % max(mesh_axis_size("model"), 1):
        kh = maybe_constrain(kh, None, None, "model", None)
        vh = maybe_constrain(vh, None, None, "model", None)
    kpos = jnp.arange(s_k)

    def one_chunk(ci, qblk):
        # qblk: (B, qc, H, hd)
        qb = jnp.swapaxes(qblk, 1, 2)                       # (B,H,qc,hd)
        logits = jnp.einsum("bhqd,bhkd->bhqk", qb, kh).astype(jnp.float32)
        logits = logits * scale
        qpos = ci * qc + jnp.arange(qc)
        mask = jnp.ones((qc, s_k), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        if causal or window:
            logits = jnp.where(mask[None, None], logits, NEG_INF)
        p = jax.nn.softmax(logits, -1).astype(vh.dtype)
        out = jnp.einsum("bhqk,bhkd->bhqd", p, vh)
        return jnp.swapaxes(out, 1, 2)                      # (B,qc,H,hd)

    body = jax.checkpoint(one_chunk) if remat_chunks else one_chunk
    if n_chunks == 1:
        out = body(0, qh[:, 0])
        return out[:, :s] if pad else out
    out = jax.lax.map(lambda args: body(*args),
                      (jnp.arange(n_chunks), jnp.swapaxes(qh, 0, 1)))
    out = jnp.swapaxes(out, 0, 1).reshape(b, n_chunks * qc, h, hd)
    return out[:, :s] if pad else out


def attention_decode(q: Array, cache: KVCache, n_heads: int) -> Array:
    """One-token attention over a (possibly ring-buffer) cache.

    q: (B, 1, H, hd); cache.k/v: (B, Kv, S, hd). Returns (B, 1, H, hd).
    """
    b, _, h, hd = q.shape
    kv = cache.k.shape[1]
    rep = n_heads // kv
    qg = q[:, 0].reshape(b, kv, rep, hd)                    # (B,Kv,rep,hd)
    logits = jnp.einsum("bkrd,bksd->bkrs", qg.astype(jnp.float32),
                        cache.k.astype(jnp.float32))
    logits = logits / jnp.sqrt(jnp.float32(hd))
    spos = jnp.arange(cache.k.shape[2])
    mask = spos[None, :] < cache.length[:, None]            # (B,S)
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, -1).astype(cache.v.dtype)
    out = jnp.einsum("bkrs,bksd->bkrd", p, cache.v)
    return out.reshape(b, 1, h, hd)


def cache_update(cache: KVCache, k_new: Array, v_new: Array,
                 pos: Array, window: int = 0) -> KVCache:
    """Insert one token's K/V at absolute position ``pos`` (B,) int32.

    With ``window`` the cache is a ring buffer of that size (RoPE is applied
    before insertion, so slot order is irrelevant to attention).
    """
    s_cache = cache.k.shape[2]
    slot = pos % s_cache if window else pos
    b = k_new.shape[0]
    bidx = jnp.arange(b)
    # k_new: (B,1,Kv,hd) -> (B,Kv,hd)
    k1 = jnp.swapaxes(k_new, 1, 2)[:, :, 0]
    v1 = jnp.swapaxes(v_new, 1, 2)[:, :, 0]
    k = cache.k.at[bidx, :, slot].set(k1.astype(cache.k.dtype))
    v = cache.v.at[bidx, :, slot].set(v1.astype(cache.v.dtype))
    length = jnp.minimum(pos + 1, s_cache)
    return KVCache(k, v, length)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_forward(x: Array, p: dict, kind: str) -> Array:
    if kind == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    if kind == "geglu":
        return (jax.nn.gelu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    # plain gelu MLP (starcoder2, whisper, grok experts)
    h = jax.nn.gelu(x @ p["w_up"] + p.get("b_up", 0))
    return h @ p["w_down"] + p.get("b_down", 0)


def mlp_init(key: Array, d: int, dff: int, kind: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d ** -0.5
    s_ff = dff ** -0.5
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": (jax.random.normal(k1, (d, dff)) * s_in).astype(dtype),
            "w_up": (jax.random.normal(k2, (d, dff)) * s_in).astype(dtype),
            "w_down": (jax.random.normal(k3, (dff, d)) * s_ff).astype(dtype),
        }
    return {
        "w_up": (jax.random.normal(k1, (d, dff)) * s_in).astype(dtype),
        "b_up": jnp.zeros((dff,), dtype),
        "w_down": (jax.random.normal(k3, (dff, d)) * s_ff).astype(dtype),
        "b_down": jnp.zeros((d,), dtype),
    }


def attn_init(key: Array, d: int, n_heads: int, n_kv: int, hd: int,
              bias: bool, dtype) -> dict:
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, n_heads * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, n_kv * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, n_kv * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (n_heads * hd, d))
               * (n_heads * hd) ** -0.5).astype(dtype),
    }
    if bias:
        p |= {"bq": jnp.zeros((n_heads * hd,), dtype),
              "bk": jnp.zeros((n_kv * hd,), dtype),
              "bv": jnp.zeros((n_kv * hd,), dtype)}
    return p


def norm_init(d: int, kind: str, dtype) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p
