"""Paper Figure 5: DRL (DDPG) training curves -- critic loss down, reward up.

Runs the DDPG agents against the LR/MNIST FL environment and reports the
slope of the reward and critic-loss sequences.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax

from repro.core import FLConfig, LGCSimulator, make_fleet_ddpg, tree_size
from repro.models.paper_models import make_mnist_task

from .common import emit


def _slope(xs) -> float:
    if len(xs) < 3:
        return 0.0
    t = np.arange(len(xs), dtype=np.float64)
    return float(np.polyfit(t, np.asarray(xs, np.float64), 1)[0])


def run(rounds: int = 200, emit_csv: bool = True) -> dict:
    task = make_mnist_task("lr", m_devices=3, n_train=2000)
    d = tree_size(task.init(jax.random.PRNGKey(0)))
    fleet = make_fleet_ddpg(3, d)
    cfg = FLConfig(rounds=rounds, eval_every=max(rounds // 8, 1))
    t0 = time.time()
    LGCSimulator(task, cfg, fleet, mode="lgc").run()
    dt = time.time() - t0
    rewards = [float(r) for rs in fleet.rewards for r in rs]
    closses = [float(l) for ls in fleet.critic_losses for l in ls]
    # windowed means (the paper's per-episode curves)
    w = max(len(rewards) // 8, 1)
    reward_curve = [float(np.mean(rewards[i:i + w]))
                    for i in range(0, len(rewards), w)]
    loss_curve = [float(np.mean(closses[i:i + w]))
                  for i in range(0, len(closses), w)] if closses else []
    out = {"rewards": rewards, "critic_losses": closses,
           "reward_curve": reward_curve, "critic_loss_curve": loss_curve,
           "reward_slope": _slope(reward_curve),
           "critic_loss_slope": _slope(loss_curve)}
    if emit_csv:
        emit("fig5_drl", dt * 1e6 / rounds,
             f"n_rewards={len(rewards)};reward_slope={out['reward_slope']:.4f};"
             f"critic_loss_slope={out['critic_loss_slope']:.4f}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    res = run(rounds=args.rounds)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
