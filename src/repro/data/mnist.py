"""Synthetic MNIST stand-in (the container is offline, so the loader is
procedural instead of a download).

Generates a deterministic, learnable 10-class 28x28 grayscale dataset:
each class has a distinct stroke template (rendered from a small set of
line/arc primitives) plus per-sample affine jitter and pixel noise.  A linear
model reaches ~90% and a small CNN >97% on it, mirroring real-MNIST relative
difficulty, which is what the paper's Figures 3-4 exercise (the ``lr_mnist``
and ``cnn_mnist`` entries of :data:`repro.models.paper_models.TASKS`).
Partitioner invariants (determinism, per-device duplicate-freedom) are
pinned by tests/test_scenarios.py::TestPartitionerProperties.
"""
from __future__ import annotations

import numpy as np

_N_CLASSES = 10
_SIDE = 28


def _class_template(c: int) -> np.ndarray:
    """A distinct 28x28 stroke pattern per class, drawn procedurally."""
    img = np.zeros((_SIDE, _SIDE), np.float32)
    yy, xx = np.mgrid[0:_SIDE, 0:_SIDE].astype(np.float32)
    cx, cy = 13.5, 13.5
    if c == 0:  # ring
        r = np.hypot(xx - cx, yy - cy)
        img[(r > 6) & (r < 10)] = 1.0
    elif c == 1:  # vertical bar
        img[:, 12:16] = 1.0
    elif c == 2:  # top arc + diagonal
        r = np.hypot(xx - cx, yy - 8)
        img[(r > 4) & (r < 7) & (yy < 10)] = 1.0
        d = np.abs((yy - 10) - (10 - (xx - 20)) * -1.2)
        img[(d < 1.8) & (yy >= 10)] = 1.0
    elif c == 3:  # two right arcs
        for oy in (8, 19):
            r = np.hypot(xx - 11, yy - oy)
            img[(r > 4) & (r < 7) & (xx > 11)] = 1.0
    elif c == 4:  # L + vertical
        img[4:16, 8:11] = 1.0
        img[13:16, 8:20] = 1.0
        img[4:24, 17:20] = 1.0
    elif c == 5:  # top bar, left bar, bottom-right arc
        img[4:7, 8:20] = 1.0
        img[4:14, 8:11] = 1.0
        r = np.hypot(xx - 12, yy - 18)
        img[(r > 4) & (r < 7) & (xx > 10)] = 1.0
    elif c == 6:  # left hook + lower ring
        img[4:20, 9:12] = 1.0
        r = np.hypot(xx - 14, yy - 19)
        img[(r > 3.5) & (r < 6.5)] = 1.0
    elif c == 7:  # top bar + steep diagonal
        img[4:7, 6:22] = 1.0
        d = np.abs((xx - 20) + (yy - 6) * 0.55)
        img[(d < 1.6) & (yy >= 6)] = 1.0
    elif c == 8:  # two rings
        for oy in (9, 19):
            r = np.hypot(xx - cx, yy - oy)
            img[(r > 3) & (r < 5.8)] = 1.0
    else:  # 9: upper ring + tail
        r = np.hypot(xx - cx, yy - 10)
        img[(r > 3.5) & (r < 6.5)] = 1.0
        img[10:24, 17:20] = 1.0
    return np.clip(img, 0, 1)


_TEMPLATES = np.stack([_class_template(c) for c in range(_N_CLASSES)])


def _jitter(rng: np.random.Generator, img: np.ndarray) -> np.ndarray:
    """Small random shift + multiplicative stroke noise + pixel noise."""
    dy, dx = rng.integers(-2, 3, 2)
    out = np.roll(np.roll(img, dy, 0), dx, 1)
    out = out * rng.uniform(0.7, 1.0)
    out = out + rng.normal(0, 0.15, out.shape).astype(np.float32)
    return np.clip(out, 0.0, 1.0).astype(np.float32)


def load_synthetic_mnist(n_train: int = 6000, n_test: int = 1000,
                         seed: int = 0) -> tuple[tuple[np.ndarray, np.ndarray],
                                                 tuple[np.ndarray, np.ndarray]]:
    """Returns ((x_train, y_train), (x_test, y_test)); x in [0,1], (N,28,28,1)."""
    rng = np.random.default_rng(seed)

    def make(n):
        y = rng.integers(0, _N_CLASSES, n).astype(np.int32)
        x = np.stack([_jitter(rng, _TEMPLATES[c]) for c in y])
        return x[..., None], y
    return make(n_train), make(n_test)


def partition_iid(x: np.ndarray, y: np.ndarray, m: int, seed: int = 0
                  ) -> list[tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(x.shape[0])
    shards = np.array_split(perm, m)
    return [(x[s], y[s]) for s in shards]


def partition_noniid(x: np.ndarray, y: np.ndarray, m: int,
                     classes_per_device: int = 4, seed: int = 0
                     ) -> list[tuple[np.ndarray, np.ndarray]]:
    """Label-skewed partition: each device sees a subset of classes."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(m):
        cls = rng.choice(_N_CLASSES, classes_per_device, replace=False)
        mask = np.isin(y, cls)
        idx = np.where(mask)[0]
        rng.shuffle(idx)
        idx = idx[: max(64, len(idx) // m)]
        out.append((x[idx], y[idx]))
    return out
