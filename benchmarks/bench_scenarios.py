"""Scenario zoo sweep: accuracy-vs-cost of fixed vs DDPG control across the
named-scenario registry (repro.core.scenario.SCENARIOS).

The paper's premise is that learned control pays off when the environment is
*dynamic*; the seed benchmarks only ever ran the memoryless "static" model.
This bench runs every registry scenario -- Gauss-Markov bandwidth,
Gilbert-Elliott burst availability, flaky/straggler devices, Dirichlet data
skew -- under (a) the fixed LGC controller and (b) a DDPG fleet, on the
batched engine, and records final accuracy next to the resource spend
(energy / money / wall time / uplink).  Rows land in ``BENCH_scenarios.json``
via ``benchmarks/run.py`` (CI uploads it as artifact).

On the Pareto scenarios (``PARETO_SCENARIOS``: bursty channels, mobile
non-iid, and the skewed-fleet ``hetero_fleet``) a third policy runs: the
heterogeneous fleet (``action_space="per_device"`` -- each device picks its
own h and per-channel ks from a profile-augmented observation, ARCH §13)
with pipelined decisions (``pipeline_decisions=True``) and the optimistic
compute prior ``h_prior=1.5`` (the untrained policy starts near
battery-capped full compute and learns savings *downward*; without it the
short-budget frontier benchmarks exploration noise, not control).

The Pareto runs use their own ``PARETO_ROUNDS`` budget rather than the
sweep's ``--rounds``: per-device control pays off through the battery
clamps, and those need enough rounds for the capped devices' shards to
converge under plain-mean aggregation.  Each hetero row therefore embeds
its *own* fixed reference run at the same budget (``fixed_*`` fields)
instead of reusing the sweep's fixed row, plus ``wall_ratio_vs_fixed``
(controller wall-clock over that reference's).
``check_regression.check_pareto`` gates the rows: hetero must
match-or-beat its fixed reference on energy or simulated time at <= 2
points of accuracy, and the pipelined wall ratio must not regress past
the committed shared-DDPG ratio.
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.core import (SCENARIOS, FLConfig, FleetDDPG, LGCSimulator,
                        run_baseline, tree_size)
from repro.core.controller import DDPGConfig, obs_dim
from repro.models.paper_models import make_mnist_task

from .common import emit

# scenarios where the 3-policy accuracy-vs-spend frontier (fixed vs shared
# DDPG vs heterogeneous per-device DDPG) is published and gated
PARETO_SCENARIOS = ("gilbert_flaky", "mobile_noniid", "hetero_fleet")

# fixed horizon for the Pareto runs (decoupled from --rounds): long enough
# for battery-capped devices' shards to converge under plain-mean
# aggregation -- at short budgets the capped devices' accuracy deficit
# dominates and the frontier measures the aggregator, not the controller
PARETO_ROUNDS = 150


def _row(scenario: str, controller: str, hist, wall: float, m: int,
         rounds: int, **extra) -> dict:
    return {
        "scenario": scenario, "controller": controller, "m_devices": m,
        "rounds": rounds, "wall_s": round(wall, 3),
        "final_loss": round(hist.loss[-1], 4),
        "final_accuracy": round(hist.accuracy[-1], 4),
        "energy_j": round(hist.energy_j[-1], 2),
        "money": round(hist.money[-1], 4),
        "time_s": round(hist.time_s[-1], 2),
        "uplink_mb": round(hist.uplink_mb[-1], 4),
        **extra,
    }


def run(scenarios=None, m: int = 8, rounds: int = 60, n_train: int = 2000,
        emit_csv: bool = True, pareto_rounds: int = PARETO_ROUNDS) -> dict:
    names = list(scenarios or SCENARIOS)
    rows = []
    for name in names:
        task = make_mnist_task("lr", m_devices=m, n_train=n_train,
                               scenario=name)
        cfg = FLConfig(rounds=rounds, eval_every=max(rounds // 4, 1),
                       scenario=name)
        t0 = time.time()
        h_fix = run_baseline(task, cfg, "lgc", h=4, engine="batched")
        wall_fix = time.time() - t0
        row_fix = _row(name, "fixed", h_fix, wall_fix, m, rounds)
        rows.append(row_fix)
        d = tree_size(task.init(jax.random.PRNGKey(0)))
        # batch_size=4 so the replay buffer warms within the bench budget
        # (a device inserts one transition per sync; the default batch of 64
        # would leave the fleet untrained and benchmark exploration noise)
        fleet = FleetDDPG(m, DDPGConfig(
            k_total_max=max(3, int(d * 0.05)), batch_size=4, seed=0))
        t0 = time.time()
        h_drl = LGCSimulator(task, cfg, fleet, mode="lgc",
                             engine="batched").run()
        wall_drl = time.time() - t0
        train_steps = int(fleet._n_train.sum())
        assert train_steps > 0, f"DDPG never trained on {name}; raise rounds"
        row_drl = _row(name, "ddpg", h_drl, wall_drl, m, rounds,
                       ddpg_train_steps=train_steps,
                       wall_ratio_vs_fixed=round(wall_drl / wall_fix, 3))
        rows.append(row_drl)
        if name in PARETO_SCENARIOS:
            # dedicated fixed reference at the Pareto budget: the sweep's
            # fixed row above ran --rounds, not PARETO_ROUNDS, so its spend
            # and accuracy are not comparable to the hetero run
            cfg_ref = FLConfig(rounds=pareto_rounds,
                               eval_every=max(pareto_rounds // 6, 1),
                               scenario=name)
            t0 = time.time()
            h_ref = run_baseline(task, cfg_ref, "lgc", h=4, engine="batched")
            wall_ref = time.time() - t0
            # max_gap=4 matches the fixed reference's h=4 sync cadence (so
            # energy / time compare like for like) and gives the fleet
            # enough sync transitions to warm its batch_size=4 replay
            n_ch = len(cfg.channels)
            het = FleetDDPG(m, DDPGConfig(
                state_dim=obs_dim(n_ch, "per_device"), n_channels=n_ch,
                action_space="per_device", h_max=4, h_prior=1.5,
                k_total_max=max(n_ch, int(d * 0.05)), batch_size=4, seed=0))
            cfg_het = FLConfig(rounds=pareto_rounds,
                               eval_every=max(pareto_rounds // 6, 1),
                               scenario=name, action_space="per_device",
                               pipeline_decisions=True, max_gap=4)
            t0 = time.time()
            h_het = LGCSimulator(task, cfg_het, het, mode="lgc",
                                 engine="batched").run()
            wall_het = time.time() - t0
            rows.append(_row(
                name, "hetero_ddpg", h_het, wall_het, m, pareto_rounds,
                ddpg_train_steps=int(het._n_train.sum()),
                wall_ratio_vs_fixed=round(wall_het / wall_ref, 3),
                fixed_final_accuracy=round(h_ref.accuracy[-1], 4),
                fixed_energy_j=round(h_ref.energy_j[-1], 2),
                fixed_money=round(h_ref.money[-1], 4),
                fixed_time_s=round(h_ref.time_s[-1], 2),
                fixed_wall_s=round(wall_ref, 3)))
        if emit_csv:
            emit(f"scenario_{name}",
                 (row_fix["wall_s"] + row_drl["wall_s"]) * 1e6 / rounds,
                 f"fixed_acc={row_fix['final_accuracy']};"
                 f"ddpg_acc={row_drl['final_accuracy']};"
                 f"fixed_energy={row_fix['energy_j']};"
                 f"ddpg_energy={row_drl['energy_j']}")
    return {"m_devices": m, "rounds": rounds,
            "pareto_rounds": pareto_rounds, "rows": rows}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--m", type=int, default=8)
    ap.add_argument("--scenarios", default=None,
                    help="comma-separated registry names (default: all)")
    ap.add_argument("--pareto-rounds", type=int, default=PARETO_ROUNDS)
    ap.add_argument("--out", default="BENCH_scenarios.json")
    args = ap.parse_args()
    names = args.scenarios.split(",") if args.scenarios else None
    res = run(scenarios=names, m=args.m, rounds=args.rounds,
              pareto_rounds=args.pareto_rounds)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
