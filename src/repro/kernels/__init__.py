"""Pallas TPU kernels for the LGC compression hot path + decode attention.

Kernels (each validated against ref.py oracles in interpret mode,
tests/test_kernels.py):
  topk_threshold   -- maxabs + 256-bin magnitude histogram (2-pass Top_k)
  layered_sparsify -- fused layered sparsify + error-feedback update
  swa_attention    -- sliding-window flash decode attention (long_500k)

``backend="pallas"`` routes both FL engines through
:func:`lgc_compress_hist`; the engines must still agree with each other on
it (tests/test_fl.py::TestEngineEquivalence::
test_pallas_backend_matches_loop_and_learns -- the equivalence ladder of
docs/ARCHITECTURE.md §1 holds per backend, not just for the exact oracle).
"""
from .ops import lgc_compress_hist, lgc_compress_hist_ref, selected_counts
from .topk_threshold import histogram, maxabs, thresholds_from_counts
from .layered_sparsify import sparsify_ef

__all__ = [
    "lgc_compress_hist", "lgc_compress_hist_ref", "selected_counts",
    "histogram", "maxabs", "thresholds_from_counts", "sparsify_ef",
]
