"""Whisper-small [arXiv:2212.04356] -- enc-dec audio; conv frontend is a
STUB: input_specs() feeds 1500 precomputed 20ms-frame embeddings (B,1500,768)
to the encoder (the assignment's modality carve-out, DESIGN.md §4)."""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", arch_type="audio",
    n_layers=12, encoder_layers=12, encoder_seq=1500,
    d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=51_865,
    mlp="gelu", norm="layernorm", use_rope=False,
    max_position=32_768,     # mechanical decode-32k support; whisper's own
                             # decoder ceiling is 448 tokens (DESIGN.md §4)
    source="arXiv:2212.04356",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="whisper-small-smoke", n_layers=2, encoder_layers=2,
        encoder_seq=64, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=512, max_position=256, remat=False, attn_q_chunk=64)
