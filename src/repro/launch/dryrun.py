import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks device count on first init.
__doc__ = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) and report
memory analysis, cost analysis, and roofline terms.  No real allocation --
all inputs are ShapeDtypeStructs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results.jsonl
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
      --shape train_4k --multipod --mode lgc

Modes (train_4k only; serving shapes always use the plain serve path):
  sync        standard data+tensor-parallel step (framework baseline)
  lgc         paper Algorithm 1 (H local steps + histogram-LGC, dense psum)
  lgc_sparse  LGC with layered sparse all_gather (multi-channel, beyond-paper)
  lgc_bucket  LGC with bucket-argmax selection (sort-free, shard-local --
              the TPU-native variant, EXPERIMENTS.md I-C6)
  fedavg      H local steps, dense exchange (no compression) -- paper baseline
"""

import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.analysis.roofline import analyze_compiled
from repro.configs import get_config, list_archs
from repro.launch import sharding_rules as rules
from repro.launch import shapes as shp
from repro.launch import compat
from repro.launch.mesh import fl_axis_name, make_production_mesh
from repro.launch.steps import (ACCUM_STEPS, LGCStepConfig,
                                make_lgc_train_step, make_prefill_step,
                                make_serve_step, make_sync_train_step)
from repro.models import transformer as tf
from repro.optim.optimizers import get_optimizer


def _abstract_params(cfg):
    return jax.eval_shape(lambda k: tf.init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              mode: str = "sync", lgc_cfg: LGCStepConfig | None = None,
              cfg_overrides: dict | None = None):
    """Lower + compile one (arch, shape, mesh, mode). Returns (report, extras)."""
    cfg = get_config(arch)
    shape = shp.SHAPES[shape_name]
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    compat.set_mesh(mesh)
    fl_ax = fl_axis_name(mesh)
    if mode in ("lgc", "lgc_sparse", "lgc_bucket", "fedavg") and cfg.fsdp:
        # (a) FL devices must hold whole replicas along the FL axis;
        # (b) FSDP params + gather inside the pod-manual shard_map region
        #     trip an XLA SpmdPartitioner CHECK (ExpandDeviceGroupsWithIota,
        #     spmd_partitioner_util.cc:504) -- recorded in EXPERIMENTS.md.
        cfg = dataclasses.replace(cfg, fsdp=False)
    if shape_name == "prefill_32k":
        cfg = dataclasses.replace(cfg, attn_q_chunk=256)

    params_sds = _abstract_params(cfg)
    pspecs = rules.param_specs(cfg, params_sds, mesh)
    specs = shp.input_specs(cfg, shape_name)

    if shape.kind == "train":
        batch_specs = rules.batch_specs(cfg, specs, mesh)
        if mode == "sync":
            opt_init, _ = get_optimizer(cfg.optimizer)
            opt_sds = jax.eval_shape(opt_init, params_sds)
            ospecs = rules.opt_state_specs(pspecs, opt_sds)
            step = make_sync_train_step(
                cfg, accum_steps=ACCUM_STEPS.get(arch, 1))
            jitted = jax.jit(step,
                             in_shardings=compat.shardings(mesh, (pspecs, ospecs, batch_specs)),
                             out_shardings=compat.shardings(mesh, (pspecs, ospecs, P())))
            args = (params_sds, opt_sds, specs)
        else:
            lgc = lgc_cfg or LGCStepConfig(
                aggregate={"lgc": "dense_masked",
                           "lgc_sparse": "sparse_gather",
                           "lgc_bucket": "bucket_sparse",
                           "fedavg": "none"}[mode])
            step = make_lgc_train_step(cfg, mesh, lgc, batch_specs,
                                       param_spec_tree=pspecs)
            n_fl = dict(zip(mesh.axis_names, mesh.devices.shape))[fl_ax]
            ef_sds = jax.eval_shape(
                lambda p: jax.tree_util.tree_map(
                    lambda x: jnp.zeros((n_fl,) + x.shape,
                                        jnp.dtype(lgc.ef_dtype)), p),
                params_sds)
            especs = rules.ef_specs(pspecs, fl_ax)
            jitted = jax.jit(step,
                             in_shardings=compat.shardings(mesh, (pspecs, especs, batch_specs)),
                             out_shardings=compat.shardings(mesh, (pspecs, especs, P())))
            args = (params_sds, ef_sds, specs)
        n_tokens = shape.global_batch * shape.seq_len

    elif shape.kind == "prefill":
        batch_specs = rules.batch_specs(cfg, specs, mesh)
        step = make_prefill_step(cfg)
        jitted = jax.jit(step, in_shardings=compat.shardings(mesh, (pspecs, batch_specs)))
        args = (params_sds, specs)
        n_tokens = shape.global_batch * shape.seq_len

    else:  # decode
        cspecs = rules.cache_specs(cfg, specs["cache"], mesh)
        tok_spec = rules.batch_specs(cfg, {"token": specs["token"]}, mesh)["token"]
        step = make_serve_step(cfg, window=shp.window_for(cfg, shape_name))
        jitted = jax.jit(step,
                         in_shardings=compat.shardings(mesh, (pspecs, tok_spec, cspecs)),
                         out_shardings=compat.shardings(mesh, (tok_spec, cspecs)))
        args = (params_sds, specs["token"], specs["cache"])
        n_tokens = shape.global_batch          # one new token per sequence

    t0 = time.time()
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mesh_desc = "2x16x16" if multi_pod else "16x16"
    report = analyze_compiled(
        compiled, cfg, arch=arch, shape=shape_name, shape_kind=shape.kind,
        n_tokens=n_tokens, mesh_desc=mesh_desc, mode=mode, n_chips=n_chips)
    extras = {"t_lower_s": round(t_lower, 1),
              "t_compile_s": round(t_compile, 1),
              "memory_analysis": str(compiled.memory_analysis())}
    return report, extras


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=list(shp.SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--mode", default="sync",
                    choices=["sync", "lgc", "lgc_sparse", "lgc_bucket", "fedavg"])
    ap.add_argument("--out", default=None, help="append JSONL here")
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--sparsity", default="0.01,0.02,0.02")
    args = ap.parse_args(argv)

    pairs = []
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(shp.SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multipod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                pairs.append((a, s, mp))

    lgc_cfg = LGCStepConfig(
        local_steps=args.local_steps,
        sparsity=tuple(float(x) for x in args.sparsity.split(",")),
        aggregate={"lgc": "dense_masked", "lgc_sparse": "sparse_gather",
                   "lgc_bucket": "bucket_sparse",
                   "fedavg": "none", "sync": "dense_masked"}[args.mode])

    failures = []
    for arch, shape_name, mp in pairs:
        tag = f"{arch} x {shape_name} x {'2x16x16' if mp else '16x16'} [{args.mode}]"
        try:
            report, extras = lower_one(arch, shape_name, multi_pod=mp,
                                       mode=args.mode, lgc_cfg=lgc_cfg)
            print(report.summary(), flush=True)
            print("   ", extras["memory_analysis"][:160], flush=True)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps({**report.asdict(), **extras}) + "\n")
        except Exception as e:  # noqa: BLE001 -- report and continue
            failures.append((tag, repr(e)))
            print(f"FAIL {tag}: {e!r}", flush=True)
    if failures:
        print(f"\n{len(failures)} failures:")
        for t, e in failures:
            print(" ", t, e[:200])
        sys.exit(1)
    print("\nall dry-runs compiled OK")


if __name__ == "__main__":
    main()
