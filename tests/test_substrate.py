"""Substrate tests: optimizers, data pipelines, checkpointing, channels."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or fallback

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.core.channels import (DEFAULT_CHANNELS, DeviceProfile, comm_cost,
                                 comp_cost, sample_channels)
from repro.data import (TokenPipeline, char_batches, load_shakespeare,
                        load_synthetic_mnist, partition_iid, partition_noniid)
from repro.optim.optimizers import (OptimizerConfig, adamw_init, adamw_update,
                                    apply_updates, get_optimizer, global_norm,
                                    sgdm_init, sgdm_update)


class TestOptimizers:
    def _quadratic(self, name):
        """Each optimizer must minimise a simple quadratic."""
        init, update = get_optimizer(
            name, OptimizerConfig(name=name, lr=0.1, warmup_steps=1,
                                  weight_decay=0.0))
        params = {"w": jnp.array([3.0, -2.0])}
        state = init(params)
        for _ in range(120):
            g = jax.tree_util.tree_map(lambda w: 2 * w, params)
            upd, state = update(g, state, params)
            params = apply_updates(params, upd)
        return float(jnp.abs(params["w"]).max())

    @pytest.mark.parametrize("name", ["adamw", "sgdm", "sgd"])
    def test_minimises_quadratic(self, name):
        assert self._quadratic(name) < 0.15

    def test_adamw_moments_dtype_and_shapes(self):
        params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
        st_ = adamw_init(params)
        assert st_.m["w"].dtype == jnp.float32
        g = {"w": jnp.ones((4, 4), jnp.bfloat16)}
        upd, st2 = adamw_update(OptimizerConfig(), g, st_, params)
        assert upd["w"].dtype == jnp.bfloat16
        assert int(st2.step) == 1

    def test_sgdm_moment_inherits_param_dtype(self):
        params = {"w": jnp.ones((4,), jnp.bfloat16)}
        st_ = sgdm_init(params)
        assert st_.momentum["w"].dtype == jnp.bfloat16

    def test_grad_clip(self):
        cfg = OptimizerConfig(grad_clip=1.0, lr=1.0, warmup_steps=1,
                              weight_decay=0.0)
        params = {"w": jnp.zeros((3,))}
        g = {"w": jnp.array([100.0, 0.0, 0.0])}
        upd, _ = sgdm_update(cfg, g, sgdm_init(params), params)
        assert float(global_norm(upd)) <= 1.01

    def test_warmup_schedule(self):
        cfg = OptimizerConfig(lr=1.0, warmup_steps=10, weight_decay=0.0)
        params = {"w": jnp.ones(())}
        state = sgdm_init(params)
        g = {"w": jnp.ones(())}
        upd1, state = sgdm_update(cfg, g, state, params)
        for _ in range(20):
            _, state = sgdm_update(cfg, g, state, params)
        upd2, _ = sgdm_update(cfg, g, state, params)
        assert abs(float(upd1["w"])) < abs(float(upd2["w"]))


class TestData:
    def test_mnist_shapes_and_determinism(self):
        (x1, y1), (xt, yt) = load_synthetic_mnist(600, 100, seed=7)
        (x2, y2), _ = load_synthetic_mnist(600, 100, seed=7)
        assert x1.shape == (600, 28, 28, 1) and xt.shape == (100, 28, 28, 1)
        np.testing.assert_array_equal(x1, x2)
        assert x1.min() >= 0 and x1.max() <= 1
        assert set(np.unique(y1)) <= set(range(10))

    def test_mnist_learnable(self):
        """Linear probe on raw pixels must beat chance by a wide margin."""
        (x, y), (xt, yt) = load_synthetic_mnist(2000, 400, seed=0)
        xf = x.reshape(len(x), -1)
        xtf = xt.reshape(len(xt), -1)
        # one ridge-regression step (closed form) on one-hot targets
        yo = np.eye(10)[y]
        w = np.linalg.solve(xf.T @ xf + 10 * np.eye(784), xf.T @ yo)
        acc = (xtf @ w).argmax(-1) == yt
        assert acc.mean() > 0.5

    def test_partitions(self):
        (x, y), _ = load_synthetic_mnist(1000, 10)
        iid = partition_iid(x, y, 4)
        assert sum(len(s[1]) for s in iid) == 1000
        non = partition_noniid(x, y, 3, classes_per_device=2)
        for xs, ys in non:
            assert len(np.unique(ys)) <= 2

    def test_shakespeare_stream(self):
        s = load_shakespeare(5000)
        assert s.shape[0] == 5000
        rng = np.random.default_rng(0)
        x, y = char_batches(s, 8, 16, rng)
        np.testing.assert_array_equal(x[:, 1:], y[:, :-1])

    def test_token_pipeline_structure(self):
        tp = TokenPipeline(vocab_size=100, seq_len=64, batch_size=4, seed=1)
        x, y = tp.next_batch()
        assert x.shape == (4, 64) and x.max() < 100
        np.testing.assert_array_equal(x[:, 1:], y[:, :-1])
        # sticky bigram: (prev*7+3)%v transitions appear often
        hits = np.mean(y == (x.astype(np.int64) * 7 + 3) % 100)
        assert hits > 0.3


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.ones((3, 2), jnp.bfloat16),
                "b": {"c": jnp.arange(5), "d": jnp.float32(2.5)}}
        save_checkpoint(str(tmp_path), 7, tree)
        assert latest_step(str(tmp_path)) == 7
        back = load_checkpoint(str(tmp_path), 7, tree)
        for l1, l2 in zip(jax.tree_util.tree_leaves(tree),
                          jax.tree_util.tree_leaves(back)):
            assert l1.dtype == l2.dtype
            np.testing.assert_array_equal(np.asarray(l1, np.float32),
                                          np.asarray(l2, np.float32))

    def test_multiple_steps(self, tmp_path):
        tree = {"w": jnp.zeros(4)}
        for s in (1, 5, 3):
            save_checkpoint(str(tmp_path), s, tree)
        assert latest_step(str(tmp_path)) == 5


class TestChannels:
    def test_table1_energy_means(self):
        e3, e4, e5 = (c.energy_mean_j_per_mb for c in DEFAULT_CHANNELS)
        assert e3 == 1296.0
        assert e4 == pytest.approx(2.2 * 1296)
        assert e5 == pytest.approx(2.5 * 2.2 * 1296)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2 ** 16))
    def test_sample_properties(self, seed):
        s = sample_channels(jax.random.PRNGKey(seed))
        assert np.all(np.asarray(s.bandwidth_mb_s) > 0)
        assert np.all(np.asarray(s.energy_j_per_mb) > 1000)

    def test_comm_cost_parallel_time(self):
        s = sample_channels(jax.random.PRNGKey(0))
        c = comm_cost(s, [1_000_000, 1_000_000, 1_000_000])
        per = [comm_cost(s, [1_000_000 if i == j else 0 for i in range(3)])
               for j in range(3)]
        # layers travel in parallel: total time = max of singles (if all up)
        if bool(np.all(np.asarray(s.up))):
            assert float(c["time_s"]) == pytest.approx(
                max(float(p["time_s"]) for p in per))
        assert float(c["energy_j"]) == pytest.approx(
            sum(float(p["energy_j"]) for p in per), rel=1e-5)

    def test_comp_cost_linear_in_h(self):
        p = DeviceProfile()
        assert comp_cost(p, 8)["energy_j"] == pytest.approx(
            2 * comp_cost(p, 4)["energy_j"])
