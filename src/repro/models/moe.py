"""Top-k Mixture-of-Experts layer (OLMoE 64e/top-8, Grok-1 8e/top-2).

Sort-based dispatch (the TPU-native "megablocks" style -- DESIGN.md §4):

  1. router top-k -> (token, expert, gate) triples, N*K rows
  2. argsort by expert id; position-in-expert from segment starts
  3. scatter rows into an (E, C, D) buffer (capacity C, overflow dropped)
  4. one batched expert matmul (E, C, D) x (E, D, F)  -- MXU friendly
  5. gather back and combine with gate weights

FLOPs scale with *active* params (E*C ~ N*K*capacity_factor) instead of the
E-times blowup of the dense-einsum formulation; the buffer is sharded over
the ``model`` axis (expert parallelism) via a sharding constraint, which is
what turns step 3/5 into the all-to-all the roofline 'collective' term sees.

``moe_dense_ref`` is the O(N*E) oracle used by unit tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import mlp_forward, mlp_init

Array = jax.Array


def moe_init(key: Array, d: int, n_experts: int, d_expert: int, kind: str,
             dtype) -> dict:
    kr, ke = jax.random.split(key)
    expert_keys = jax.random.split(ke, n_experts)
    experts = jax.vmap(lambda k: mlp_init(k, d, d_expert, kind, dtype)
                       )(expert_keys)
    return {
        "router": (jax.random.normal(kr, (d, n_experts)) * d ** -0.5
                   ).astype(jnp.float32),
        "experts": experts,     # each leaf has leading E dim
    }


def _router(x2: Array, w: Array, k: int) -> tuple[Array, Array, Array]:
    """x2: (N, D) -> gates (N, K), ids (N, K), aux load-balance loss."""
    logits = (x2.astype(jnp.float32) @ w)                  # (N, E)
    probs = jax.nn.softmax(logits, -1)
    gates, ids = jax.lax.top_k(probs, k)
    gates = gates / jnp.sum(gates, -1, keepdims=True)
    # Switch-style aux loss: E * sum_e f_e * p_e
    e = w.shape[1]
    density = jnp.mean(jax.nn.one_hot(ids, e, dtype=jnp.float32), axis=(0, 1))
    p_mean = jnp.mean(probs, 0)
    aux = e * jnp.sum(density * p_mean)
    return gates, ids, aux


def moe_forward(x: Array, p: dict, *, n_experts: int, top_k: int,
                capacity_factor: float = 1.25,
                mlp_kind: str = "swiglu",
                shard_buffer=None) -> tuple[Array, Array]:
    """x: (B, S, D) -> (out (B,S,D), aux_loss scalar)."""
    b, s, d = x.shape
    n = b * s
    x2 = x.reshape(n, d)
    gates, ids, aux = _router(x2, p["router"], top_k)

    nk = n * top_k
    flat_e = ids.reshape(nk)                       # expert of each row
    flat_tok = jnp.arange(nk) // top_k             # source token of each row
    flat_gate = gates.reshape(nk)

    order = jnp.argsort(flat_e)                    # stable sort by expert
    se = flat_e[order]
    stok = flat_tok[order]

    # position of each row within its expert segment
    starts = jnp.searchsorted(se, jnp.arange(n_experts))   # (E,)
    pos = jnp.arange(nk) - starts[se]
    cap = max(1, int(nk / n_experts * capacity_factor))
    valid = pos < cap
    dest = jnp.where(valid, se * cap + pos, n_experts * cap)  # drop slot

    # GATHER-based dispatch (perf iteration I-A, EXPERIMENTS.md §Perf):
    # scattering token VECTORS into the expert buffer made GSPMD replicate
    # the token matrix across the expert-parallel axis (collective-bound);
    # instead scatter only int32 *row indices* (tiny) and move all vector
    # data with gathers, which partition as passthrough dims.
    slot_src = jnp.full((n_experts * cap + 1,), n, jnp.int32)
    slot_src = slot_src.at[dest].set(stok)          # slot -> source token
    slot_src = slot_src[:-1]
    x2p = jnp.concatenate([x2, jnp.zeros((1, d), x.dtype)], 0)
    buf = x2p[slot_src].reshape(n_experts, cap, d)  # gather

    # expert-parallel layout: shard over E when E divides the model axis
    # (olmoe 64e), else over the hidden dim (grok 8e < 16 shards)
    from .layers import maybe_constrain, mesh_axis_size
    e_par = n_experts % max(mesh_axis_size("model"), 1) == 0
    shard_buffer = shard_buffer or (
        (lambda t: maybe_constrain(t, "model", None, None)) if e_par
        else (lambda t: maybe_constrain(t, None, None, "model")))
    buf = shard_buffer(buf)

    out_buf = jax.vmap(lambda xe, pe: mlp_forward(xe, pe, mlp_kind)
                       )(buf, p["experts"])        # (E, C, D)
    out_buf = shard_buffer(out_buf)

    # combine: gather each row's output back, invert the sort permutation,
    # and reduce the K slots per token with the gate weights -- no scatter.
    rows = out_buf.reshape(n_experts * cap, d)
    picked = jnp.where(valid[:, None],
                       rows[jnp.minimum(dest, n_experts * cap - 1)], 0)
    inv = jnp.zeros((nk,), jnp.int32).at[order].set(jnp.arange(nk))
    per_slot = picked[inv].reshape(n, top_k, d)     # token-major
    # I-A3: pin the combined rows back to the token (data) layout so the
    # expert->token movement lowers as one all-to-all-ish reshard instead
    # of replication (EXPERIMENTS.md §Perf)
    per_slot = maybe_constrain(per_slot, "data", None, None)
    y = jnp.einsum("nkd,nk->nd", per_slot.astype(jnp.float32), gates)
    return y.astype(x.dtype).reshape(b, s, d), aux


def moe_dense_ref(x: Array, p: dict, *, n_experts: int, top_k: int,
                  mlp_kind: str = "swiglu") -> tuple[Array, Array]:
    """O(N*E) oracle: run every expert on every token, weight by gates."""
    b, s, d = x.shape
    x2 = x.reshape(-1, d)
    gates, ids, aux = _router(x2, p["router"], top_k)
    all_out = jax.vmap(lambda pe: mlp_forward(x2, pe, mlp_kind),
                       out_axes=1)(p["experts"])   # (N, E, D)
    w = jnp.zeros((x2.shape[0], n_experts), jnp.float32)
    w = jax.vmap(lambda wr, i, g: wr.at[i].add(g))(w, ids, gates)
    y = jnp.einsum("ne,ned->nd", w, all_out.astype(jnp.float32))
    return y.astype(x.dtype).reshape(b, s, d), aux
