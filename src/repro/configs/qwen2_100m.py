"""Qwen2-family ~128M-parameter config for the federated 100M LGC stack.

This is the registry home of the config that used to live privately in
``examples/train_100m_lgc.py`` (whose seed version actually built a 47M
model).  At d_model=768 / 12 layers / 32k tied vocab the flattened
gradient tree is ~1.28e8 elements -- past ``PALLAS_MIN_ELEMS`` on every
matmul leaf, i.e. real LGC-kernel territory (docs/ARCHITECTURE.md §12).
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-100m", arch_type="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
    d_ff=3072, vocab_size=32_000,
    qkv_bias=True, tie_embeddings=True,
    mlp="swiglu", norm="rmsnorm",
    remat=False, attn_q_chunk=128, loss_chunk=256,
    source="arXiv:2407.10671 (scaled)",
)


def smoke() -> ArchConfig:
    """Tiny same-shape variant for tests and the CI docs lane."""
    return dataclasses.replace(
        CONFIG, name="qwen2-100m-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, vocab_size=512, attn_q_chunk=64,
        loss_chunk=64)
