"""Learning-based control algorithm (paper §3): per-device DDPG.

Each device runs its own agent deciding, at every synchronization, its
  * H_m      -- number of local computation steps until the next sync
  * D_{m,n}  -- gradient entries allocated to channel n (the LGC layer sizes)

State  (Eq. 11-12): per-resource communication/computation consumption.
Action (Eq. 13):    a = (H, D_1..D_N), continuous, squashed by tanh.
Reward (Eq. 14-16): weighted ratio of utility U = (loss drop)/(spend).

DDPG (Lillicrap et al. 2015): deterministic actor pi(s|theta_pi), critic
Q(s,a|theta_Q), replay buffer, soft target networks, Gaussian exploration
noise.

Two views share every piece of math, every compiled program, and the
counter-based :func:`repro.core.fl.stream_key` randomness (exploration
noise on ``TAG_CTRL_NOISE``, replay sampling on ``TAG_CTRL_SAMPLE``), so
they are bit-identical for a fixed seed:

* :class:`FleetDDPG`      -- M agents stacked into leading-axis-(M, .)
  pytrees with a device-axis JAX replay buffer; act / exploration noise /
  the DDPG train step run as lax.map'd (M, .) programs so a constant
  number of jitted calls serves the whole fleet per sync boundary (the
  batched controller protocol in :mod:`repro.core.fl`).  Device m is
  seeded ``PRNGKey(seed + 17*m)``.
* :class:`DDPGController` -- one agent, one device: a fleet of size one
  exposing the classic per-device interface; element m of
  :func:`make_ddpg_controllers` equals device m of
  :func:`make_fleet_ddpg`, bit for bit.

Invariant (keep it): per-device float math runs through ``lax.map`` bodies,
NOT vmap -- XLA:CPU picks batch-shape-dependent fusion schedules for
vmapped math, which would break the fleet==list bit-identity pinned by
tests/test_fl.py::TestEngineEquivalence::test_fleet_matches_agent_list
(docs/ARCHITECTURE.md §6).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fl import (TAG_CTRL_NOISE, TAG_CTRL_SAMPLE, RoundDecision,
                           stream_key)
from repro.optim.optimizers import (AdamWState, OptimizerConfig, adamw_update,
                                    apply_updates)

Array = jax.Array


# ---------------------------------------------------------------------------
# tiny MLPs
# ---------------------------------------------------------------------------

def _mlp_init(key, sizes):
    params = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        k1, key = jax.random.split(key)
        params.append({"w": jax.random.normal(k1, (a, b)) * (2 / a) ** 0.5,
                       "b": jnp.zeros((b,))})
    return params


def _mlp_apply(params, x, final_tanh=False):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return jnp.tanh(x) if final_tanh else x


# ---------------------------------------------------------------------------
# shared pure pieces: state norm, action decode, act, train step
# ---------------------------------------------------------------------------

def _norm_states(states: np.ndarray) -> np.ndarray:
    """log-scale resources so the MLPs see O(1) numbers."""
    return np.log1p(np.maximum(states, 0)).astype(np.float32)


# observation layouts per action space (docs/ARCHITECTURE.md §13): the
# "shared" space observes the four Eq. (11) resource spends; "per_device"
# appends the device's own profile -- battery, compute-time multiplier --
# and the realized per-channel state from the scenario carry, so policies
# can condition on fleet heterogeneity.  BATTERY_COL indexes battery in the
# RAW (un-normalized) per_device state vector; decode_actions reads it for
# the energy clamp.
SPEND_DIM = 4                 # energy, money, time, mb (Eq. 11)
PROFILE_DIM = 2               # battery, compute-time multiplier
BATTERY_COL = SPEND_DIM


def obs_dim(n_channels: int, action_space: str) -> int:
    """Width of the observation vector the simulator builds
    (:meth:`repro.core.fl.LGCSimulator._controller_states`) for each
    action space; ``DDPGConfig.state_dim`` must equal it."""
    if action_space == "shared":
        return SPEND_DIM
    if action_space == "per_device":
        return SPEND_DIM + PROFILE_DIM + n_channels
    raise ValueError(f"unknown action_space {action_space!r}; "
                     f"expected 'shared' or 'per_device'")


def decode_actions(a: np.ndarray, h_max: int, k_total_max: int,
                   n_channels: int, battery: np.ndarray | None = None
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Decode raw tanh actions ``(..., 1+C)`` into ``h (...,)`` local-step
    counts and ``ks (..., C)`` per-channel budgets with ``1 <= ks`` and
    ``sum(ks) <= max(n_channels, k_total_max)`` -- the per-device budget
    clamp holds row by row, not just in aggregate.

    ``battery`` (same leading shape as ``a``, values in [0, 1]) applies the
    per-device energy clamp of the heterogeneous action space:
    ``h <= 1 + floor(battery * (h_max - 1))``, so a zero-battery device is
    pinned to the one mandatory local step no matter what its policy says.
    ``battery=None`` (the shared action space) leaves ``h`` untouched.

    Elementwise numpy, so decoding one action and decoding a stacked batch
    of them are bit-identical -- the fleet and the per-device agents share
    this decoder.
    """
    a = np.asarray(a, np.float32)
    squeeze = a.ndim == 1
    a = np.atleast_2d(a)
    h = np.rint((a[:, 0] + 1) / 2 * (h_max - 1)).astype(np.int64) + 1
    if battery is not None:
        soc = np.clip(np.atleast_1d(np.asarray(battery, np.float64)), 0.0, 1.0)
        h_cap = 1 + np.floor(soc * (h_max - 1)).astype(np.int64)
        h = np.minimum(h, h_cap)
    # channel allocations: softmax-ish positive split of the budget
    w = np.exp(2.0 * a[:, 1:])
    w = w / w.sum(-1, keepdims=True)
    k_total = max(n_channels, k_total_max)
    ks = np.maximum((w * k_total).astype(np.int64), 1)
    # raising rounded-down layers to >= 1 can overshoot the budget by up to
    # C-1 coordinates; shave the largest layer until the budget holds (the
    # largest is >= 2 whenever the sum exceeds k_total >= C, so ks stays >= 1)
    for _ in range(n_channels):
        over = ks.sum(-1) > k_total
        if not over.any():
            break
        rows = np.nonzero(over)[0]
        ks[rows, np.argmax(ks[rows], -1)] -= 1
    if squeeze:
        return h[0], ks[0]
    return h, ks


def _act_raw(actor, s, key, sigma):
    """Deterministic policy + clipped Gaussian exploration noise."""
    a = _mlp_apply(actor, s, final_tanh=True)
    return jnp.clip(a + sigma * jax.random.normal(key, a.shape), -1.0, 1.0)


# The fleet runs its per-device float math through lax.map (one scanned
# program), NOT vmap: XLA:CPU lowers batched matmul / tanh to batch-shape-
# dependent vectorized kernels whose FMA/fusion schedules drift ulps across
# batch sizes, while a scan body is one computation whose compilation does
# not depend on the trip count.  One jitted dispatch per fleet call either
# way -- which is what removes the M host round-trips -- and a size-1 fleet
# (DDPGController) runs the same programs, so list and fleet are
# bit-identical.

@jax.jit
def _act_fleet(actor, s, bases, n_acts, sigmas):
    return jax.lax.map(
        lambda args: _act_raw(args[0], args[1],
                              stream_key(args[2], TAG_CTRL_NOISE, args[3]),
                              args[4]),
        (actor, s, bases, n_acts, sigmas))


@jax.jit
def _policy_fleet(actor, s):
    return jax.lax.map(lambda args: _mlp_apply(args[0], args[1],
                                               final_tanh=True), (actor, s))


@functools.lru_cache(maxsize=None)
def _train_step(gamma: float, tau: float, lr: float):
    """One DDPG update (critic TD step, actor ascent, soft target update);
    pure, the lax.map body of the fleet train program."""
    ocfg = OptimizerConfig(lr=lr, warmup_steps=1, weight_decay=0.0)

    def critic_loss(critic, actor_t, critic_t, s, a, r, s2):
        a2 = _mlp_apply(actor_t, s2, final_tanh=True)
        q_next = _mlp_apply(critic_t, jnp.concatenate([s2, a2], -1))[:, 0]
        y = r + gamma * q_next                          # Eq. (18)
        q = _mlp_apply(critic, jnp.concatenate([s, a], -1))[:, 0]
        return jnp.mean((q - jax.lax.stop_gradient(y)) ** 2)

    def actor_loss(actor, critic, s):
        a = _mlp_apply(actor, s, final_tanh=True)
        q = _mlp_apply(critic, jnp.concatenate([s, a], -1))
        return -jnp.mean(q)

    def step(actor, critic, actor_t, critic_t, opt_a, opt_c, s, a, r, s2):
        cl, gc = jax.value_and_grad(critic_loss)(critic, actor_t,
                                                 critic_t, s, a, r, s2)
        upd, opt_c = adamw_update(ocfg, gc, opt_c, critic)
        critic = apply_updates(critic, upd)
        al, ga = jax.value_and_grad(actor_loss)(actor, critic, s)
        upd, opt_a = adamw_update(ocfg, ga, opt_a, actor)
        actor = apply_updates(actor, upd)
        soft = lambda t, o: jax.tree_util.tree_map(
            lambda x, y: (1 - tau) * x + tau * y, t, o)
        return actor, critic, soft(actor_t, actor), soft(critic_t, critic), \
            opt_a, opt_c, cl

    return step


@functools.lru_cache(maxsize=None)
def _insert_sample_jit(batch_size: int, capacity: int):
    """Ring-buffer insert + per-device replay sampling for a whole fleet:
    exact memory ops + counter-based key bits, one jitted call."""

    def add_row(buf, i, v):
        return jax.lax.dynamic_update_slice(
            buf, v[None].astype(buf.dtype), (i,) + (0,) * v.ndim)

    def insert_sample(buf_s, buf_a, buf_r, buf_s2, n, idx,
                      s, a, r, s2, add_mask, bases, n_trains):
        ins = jax.vmap(lambda B, i, v, mk: jnp.where(mk, add_row(B, i, v), B))
        buf_s = ins(buf_s, idx, s, add_mask)
        buf_a = ins(buf_a, idx, a, add_mask)
        buf_r = ins(buf_r, idx, r, add_mask)
        buf_s2 = ins(buf_s2, idx, s2, add_mask)
        n2 = jnp.where(add_mask, jnp.minimum(n + 1, capacity), n)
        idx2 = jnp.where(add_mask, (idx + 1) % capacity, idx)
        train_mask = add_mask & (n2 >= batch_size)
        sample = jax.vmap(lambda base, n_train, nn:
                          jax.random.randint(
                              stream_key(base, TAG_CTRL_SAMPLE, n_train),
                              (batch_size,), 0, jnp.maximum(nn, 1)))
        sidx = sample(bases, n_trains, n2)                   # (M, B)
        gather = jax.vmap(lambda B, i: B[i])
        batch = (gather(buf_s, sidx), gather(buf_a, sidx),
                 gather(buf_r, sidx), gather(buf_s2, sidx))
        return buf_s, buf_a, buf_r, buf_s2, n2, idx2, train_mask, batch

    return jax.jit(insert_sample)


@functools.lru_cache(maxsize=None)
def _train_fleet_jit(gamma: float, tau: float, lr: float):
    """The fleet train program: lax.map of the per-device DDPG step.

    lax.map (one scanned program), NOT vmap, and in its OWN jit: XLA:CPU
    picks batch-shape-dependent matmul/tanh kernels for (M, B, .) shapes --
    and module-level fusion can perturb them too -- so anything else drifts
    ulps from the per-device agents.  The scan body here compiles to exactly
    the single-device program, keeping the fleet bit-identical to a
    DDPGController list."""
    step = _train_step(gamma, tau, lr)
    return jax.jit(lambda stacks, s, a, r, s2: jax.lax.map(
        lambda args: step(*args), (*stacks, s, a, r, s2)))


@jax.jit
def _gather_rows(tree, idx):
    """Take device rows ``idx`` from every leaf (exact memory op)."""
    return jax.tree_util.tree_map(lambda x: x[idx], tree)


@jax.jit
def _scatter_rows(dst, src, idx):
    """Write ``src`` rows back at device rows ``idx``.  ``idx`` may repeat
    (padding rows duplicate a real device); duplicates carry identical
    values, so the scatter is deterministic."""
    return jax.tree_util.tree_map(lambda d, s: d.at[idx].set(s), dst, src)


# ---------------------------------------------------------------------------
# DDPG agent
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DDPGConfig:
    # observation width: must equal obs_dim(n_channels, action_space) --
    # 4 (energy, money, time, mb per Eq. 11) for "shared", 4 + 2 + C
    # (+ battery, compute multiplier, per-channel state) for "per_device".
    # Validated below: the observation builder and the replay buffer take
    # their widths from here, so a silent mismatch would corrupt training.
    state_dim: int = 4
    n_channels: int = 3
    h_max: int = 8               # cap on local steps (paper's H bound)
    k_total_max: int = 0         # max coords/round; set from model size
    hidden: int = 64
    gamma: float = 0.95          # discount (paper's gamma_m)
    tau: float = 0.01            # soft target update
    buffer_size: int = 4096
    batch_size: int = 64
    noise_sigma: float = 0.2
    noise_decay: float = 0.999
    lr: float = 1e-3
    seed: int = 0
    # Optimistic compute prior: added to the raw h action (column 0) before
    # decode, then clipped back to [-1, 1].  With h_prior=1.0 an untrained
    # policy starts at the battery-capped maximum compute -- the fixed
    # baseline's operating point -- and has to *learn* to save resources
    # downward (the spend-normalized reward points that way), instead of
    # exploring from half compute and paying the accuracy before earning
    # the savings.  Decode-side only: the replay buffer stores the raw
    # actor action, so the critic still sees the policy's own space.
    # 0.0 keeps the pre-ARCH-§13 behavior bit-exactly.
    h_prior: float = 0.0
    # "shared" -- the pre-§13 space: every device decides (h, k_1..k_C)
    # from the 4-wide spend state.  "per_device" -- the heterogeneous
    # space: profile-augmented observations, battery-clamped h_m, uniform
    # max_gap sync windows with a masked-step scan (ARCHITECTURE.md §13).
    action_space: str = "shared"

    def __post_init__(self):
        expected = obs_dim(self.n_channels, self.action_space)  # validates
        if self.state_dim != expected:
            raise ValueError(
                f"DDPGConfig.state_dim={self.state_dim} does not match the "
                f"observation vector the simulator builds for "
                f"action_space={self.action_space!r} with "
                f"{self.n_channels} channels: expected width {expected} "
                f"(see repro.core.controller.obs_dim)")


class ReplayBuffer:
    """Host-side reference of the fleet's device-axis ring buffer semantics
    (insert at idx mod capacity, uniform sample over the filled prefix);
    exercised by tests, not by the production controllers."""

    def __init__(self, capacity: int, state_dim: int, action_dim: int):
        self.s = np.zeros((capacity, state_dim), np.float32)
        self.a = np.zeros((capacity, action_dim), np.float32)
        self.r = np.zeros((capacity,), np.float32)
        self.s2 = np.zeros((capacity, state_dim), np.float32)
        self.n, self.idx, self.capacity = 0, 0, capacity

    def add(self, s, a, r, s2):
        i = self.idx
        self.s[i], self.a[i], self.r[i], self.s2[i] = s, a, r, s2
        self.idx = (i + 1) % self.capacity
        self.n = min(self.n + 1, self.capacity)

    def sample(self, rng: np.random.Generator, batch: int):
        idx = rng.integers(0, self.n, batch)
        return self.s[idx], self.a[idx], self.r[idx], self.s2[idx]


class DDPGController:
    """One device's agent: the per-device view of a single-device fleet.

    Implements the classic controller interface (``act(state) ->
    RoundDecision``, ``reward(loss_drop, new_state)``) consumed through the
    :class:`repro.core.fl.ControllerFleet` shim.  Internally this is a
    :class:`FleetDDPG` of size one -- the per-device and fleet paths run
    the *same* compiled programs (XLA:CPU picks value-visible FMA/fusion
    schedules per program, so sharing the executables, not just the math,
    is what makes a list of these bit-identical to one (M, .) fleet).
    """

    def __init__(self, cfg: DDPGConfig):
        self.cfg = cfg
        self.action_dim = 1 + cfg.n_channels
        self._fleet = FleetDDPG(1, cfg)

    # -- stacked state, exposed unstacked (row 0) ------------------------
    @property
    def actor(self):
        return jax.tree_util.tree_map(lambda x: x[0], self._fleet.actor)

    @property
    def critic(self):
        return jax.tree_util.tree_map(lambda x: x[0], self._fleet.critic)

    @property
    def actor_t(self):
        return jax.tree_util.tree_map(lambda x: x[0], self._fleet.actor_t)

    @property
    def critic_t(self):
        return jax.tree_util.tree_map(lambda x: x[0], self._fleet.critic_t)

    @property
    def sigma(self) -> float:
        return float(self._fleet._sigma[0])

    @property
    def rewards(self) -> list[float]:
        return self._fleet.rewards[0]

    @property
    def critic_losses(self) -> list[float]:
        return self._fleet.critic_losses[0]

    # -- controller interface -------------------------------------------
    def act(self, state: np.ndarray) -> RoundDecision:
        h, ks = self._fleet.act(np.asarray(state, np.float32)[None])
        return RoundDecision(int(h[0]), [int(k) for k in ks[0]])

    def allocation(self, state: np.ndarray) -> RoundDecision:
        """Greedy decision for ``state`` (no exploration noise; advances no
        random stream) -- the public read-only view of the learned policy."""
        h, ks = self._fleet.allocation(np.asarray(state, np.float32)[None])
        return RoundDecision(int(h[0]), [int(k) for k in ks[0]])

    def reward(self, loss_drop: float, new_state: np.ndarray):
        """Called by the simulator after the round (Eq. 14-16 computed from
        loss drop and the *incremental* spend recorded in the state)."""
        self._fleet.observe(np.array([loss_drop], np.float64),
                            np.asarray(new_state, np.float32)[None])


# ---------------------------------------------------------------------------
# the fleet: M agents, one jitted call per sync boundary
# ---------------------------------------------------------------------------

class FleetDDPG:
    """A bank of M DDPG agents stacked on a leading device axis.

    Implements the batched controller protocol of :mod:`repro.core.fl`:
    ``act`` runs every masked device's policy + exploration noise in one
    jitted call; ``observe`` inserts (s, a, r, s') transitions into the
    device-axis replay buffer, samples replay batches, and runs the DDPG
    train step for every device whose buffer is warm -- a constant number
    of jitted calls per boundary, replacing M host round-trips.

    Per-device randomness is counter-based (``stream_key`` on the device's
    own ``PRNGKey(seed + 17*m)``) and the float math runs through
    batch-independent lax.map bodies, so a fleet is bit-identical to the
    list ``make_ddpg_controllers`` builds with the same arguments.
    """

    def __init__(self, m_devices: int, cfg: DDPGConfig):
        self.cfg, self.m = cfg, m_devices
        self.action_dim = 1 + cfg.n_channels
        bases, actors, critics = [], [], []
        for i in range(m_devices):
            base = jax.random.PRNGKey(cfg.seed + 17 * i)
            ka, kc = jax.random.split(base)
            bases.append(base)
            actors.append(_mlp_init(ka, [cfg.state_dim, cfg.hidden,
                                         cfg.hidden, self.action_dim]))
            critics.append(_mlp_init(kc, [cfg.state_dim + self.action_dim,
                                          cfg.hidden, cfg.hidden, 1]))
        stack = lambda trees: jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *trees)
        self._bases = jnp.stack(bases)
        self.actor, self.critic = stack(actors), stack(critics)
        self.actor_t = jax.tree_util.tree_map(jnp.copy, self.actor)
        self.critic_t = jax.tree_util.tree_map(jnp.copy, self.critic)
        self.opt_a = self._opt_init(self.actor)
        self.opt_c = self._opt_init(self.critic)
        # device-axis ring replay buffer
        cap = cfg.buffer_size
        self._buf_s = jnp.zeros((m_devices, cap, cfg.state_dim), jnp.float32)
        self._buf_a = jnp.zeros((m_devices, cap, self.action_dim), jnp.float32)
        self._buf_r = jnp.zeros((m_devices, cap), jnp.float32)
        self._buf_s2 = jnp.zeros((m_devices, cap, cfg.state_dim), jnp.float32)
        self._n = np.zeros(m_devices, np.int64)
        self._idx = np.zeros(m_devices, np.int64)
        # host-side per-device event counters / exploration schedule
        self._n_act = np.zeros(m_devices, np.int64)
        self._n_train = np.zeros(m_devices, np.int64)
        self._sigma = np.full(m_devices, cfg.noise_sigma, np.float64)
        self._last_s = np.zeros((m_devices, cfg.state_dim), np.float32)
        self._last_a = np.zeros((m_devices, self.action_dim), np.float32)
        self._has_last = np.zeros(m_devices, bool)
        self.needs_reward = np.ones(m_devices, bool)
        self.rewards: list[list[float]] = [[] for _ in range(m_devices)]
        self.critic_losses: list[list[float]] = [[] for _ in range(m_devices)]
        self._insert_sample = _insert_sample_jit(cfg.batch_size, cap)
        self._train = _train_fleet_jit(cfg.gamma, cfg.tau, cfg.lr)

    def _opt_init(self, stacked) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(jnp.zeros((self.m,), jnp.int32),
                          jax.tree_util.tree_map(zeros, stacked),
                          jax.tree_util.tree_map(zeros, stacked))

    def _mask(self, mask) -> np.ndarray:
        return (np.ones(self.m, bool) if mask is None
                else np.asarray(mask, bool))

    def _check_width(self, states: np.ndarray) -> np.ndarray:
        """Observation width must match cfg.state_dim (the replay buffer and
        MLPs are built from it); raise with both shapes instead of silently
        training on a misaligned state vector."""
        states = np.asarray(states, np.float32)
        if states.shape[-1] != self.cfg.state_dim:
            raise ValueError(
                f"observation width {states.shape[-1]} (states shape "
                f"{states.shape}) does not match DDPGConfig.state_dim="
                f"{self.cfg.state_dim} for action_space="
                f"{self.cfg.action_space!r}")
        return states

    def _battery(self, states: np.ndarray) -> np.ndarray | None:
        """Battery column of the RAW per_device state (None when shared)."""
        if self.cfg.action_space != "per_device":
            return None
        return states[:, BATTERY_COL]

    def _with_prior(self, a: np.ndarray) -> np.ndarray:
        """Apply the optimistic compute prior (cfg.h_prior) to the raw h
        action before decode; identity at the 0.0 default."""
        if not self.cfg.h_prior:
            return a
        a = a.copy()
        a[:, 0] = np.clip(a[:, 0] + self.cfg.h_prior, -1.0, 1.0)
        return a

    # -- batched controller protocol ------------------------------------
    def act(self, states: np.ndarray, mask: np.ndarray | None = None
            ) -> tuple[np.ndarray, np.ndarray]:
        """(h (M,), ks (M, C)) for the masked devices, one jitted call."""
        mask = self._mask(mask)
        states = self._check_width(states)
        s = _norm_states(states)
        a = np.asarray(_act_fleet(
            self.actor, jnp.asarray(s), self._bases,
            jnp.asarray(self._n_act, jnp.int32),
            jnp.asarray(self._sigma, jnp.float32))).astype(np.float32)
        self._last_s[mask] = s[mask]
        self._last_a[mask] = a[mask]
        self._has_last |= mask
        self._n_act[mask] += 1
        self._sigma[mask] *= self.cfg.noise_decay
        cfg = self.cfg
        return decode_actions(self._with_prior(a), cfg.h_max,
                              cfg.k_total_max, cfg.n_channels,
                              battery=self._battery(states))

    def observe(self, loss_drops: np.ndarray, new_states: np.ndarray,
                mask: np.ndarray | None = None):
        """Reward + replay insert + (buffer-warm) train for all masked
        devices at once."""
        new_states = self._check_width(new_states)
        mask = self._mask(mask) & self._has_last
        if not mask.any():
            return
        s2 = _norm_states(new_states)
        spend = np.maximum(s2 - self._last_s, 1e-6).sum(-1)
        r = np.clip(np.asarray(loss_drops, np.float64)
                    / spend.astype(np.float64), -10.0, 10.0)
        for i in np.nonzero(mask)[0]:
            self.rewards[i].append(float(r[i]))
        (self._buf_s, self._buf_a, self._buf_r, self._buf_s2,
         n2, idx2, train_mask, batch) = self._insert_sample(
            self._buf_s, self._buf_a, self._buf_r, self._buf_s2,
            jnp.asarray(self._n, jnp.int32), jnp.asarray(self._idx, jnp.int32),
            jnp.asarray(self._last_s), jnp.asarray(self._last_a),
            jnp.asarray(r, jnp.float32), jnp.asarray(s2),
            jnp.asarray(mask), self._bases,
            jnp.asarray(self._n_train, jnp.int32))
        self._n = np.asarray(n2, np.int64)
        self._idx = np.asarray(idx2, np.int64)
        tr_idx = np.nonzero(np.asarray(train_mask))[0]
        if len(tr_idx):
            # train only the buffer-warm devices: gather their rows, pad to
            # a power of two (few compiled sizes) by repeating the first
            # trained device, scan the per-device step over the small stack,
            # scatter back.  Train cost scales with the trained count, not
            # M, and the map body stays the shared bit-exact program.
            p = 1 << (len(tr_idx) - 1).bit_length()
            pad = jnp.asarray(np.concatenate(
                [tr_idx, np.full(p - len(tr_idx), tr_idx[0])]), jnp.int32)
            old = (self.actor, self.critic, self.actor_t, self.critic_t,
                   self.opt_a, self.opt_c)
            new = self._train(_gather_rows(old, pad),
                              *(b[pad] for b in batch))
            (self.actor, self.critic, self.actor_t, self.critic_t,
             self.opt_a, self.opt_c) = _scatter_rows(old, new[:6], pad)
            cl_np = np.asarray(new[6])
            for j, i in enumerate(tr_idx):
                self.critic_losses[i].append(float(cl_np[j]))
            self._n_train[tr_idx] += 1
        self._has_last[mask] = False

    def allocation(self, states: np.ndarray
                   ) -> tuple[np.ndarray, np.ndarray]:
        """Greedy (noise-free) decisions for every device; advances no
        random stream -- the public read-only view of the learned policies.
        A single (S,) probe state is broadcast to all M devices."""
        states = self._check_width(np.atleast_2d(states))
        if states.shape[0] == 1:
            states = np.broadcast_to(states, (self.m, states.shape[1]))
        s = _norm_states(states)
        a = np.asarray(_policy_fleet(self.actor, jnp.asarray(s)))
        cfg = self.cfg
        return decode_actions(self._with_prior(a), cfg.h_max,
                              cfg.k_total_max, cfg.n_channels,
                              battery=self._battery(states))


def make_ddpg_controllers(m_devices: int, model_dim: int,
                          n_channels: int = 3, h_max: int = 8,
                          sparsity: float = 0.05, seed: int = 0,
                          action_space: str = "shared"
                          ) -> list[DDPGController]:
    """One agent per device (paper: per-device policies); the reference the
    vectorized :func:`make_fleet_ddpg` bank is bit-identical to."""
    return [DDPGController(DDPGConfig(
        state_dim=obs_dim(n_channels, action_space),
        n_channels=n_channels, h_max=h_max,
        k_total_max=max(n_channels, int(model_dim * sparsity)),
        seed=seed + 17 * m, action_space=action_space))
        for m in range(m_devices)]


def make_fleet_ddpg(m_devices: int, model_dim: int,
                    n_channels: int = 3, h_max: int = 8,
                    sparsity: float = 0.05, seed: int = 0,
                    action_space: str = "shared") -> FleetDDPG:
    """The fleet equivalent of :func:`make_ddpg_controllers` (same per-device
    seeds, same decisions, one jitted call per sync boundary).
    ``action_space="per_device"`` sizes the observation width for the
    profile-augmented heterogeneous space (pair with
    ``FLConfig(action_space="per_device")``)."""
    return FleetDDPG(m_devices, DDPGConfig(
        state_dim=obs_dim(n_channels, action_space),
        n_channels=n_channels, h_max=h_max,
        k_total_max=max(n_channels, int(model_dim * sparsity)),
        seed=seed, action_space=action_space))
