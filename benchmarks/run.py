"""Benchmark orchestrator: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (harness contract).  Reduced
budgets so the whole suite finishes in minutes on CPU; each bench_* module
has a __main__ with --rounds/--out for the full curves used in
EXPERIMENTS.md.
"""
from __future__ import annotations


def main() -> None:
    from benchmarks import (bench_compressor_throughput,
                            bench_convergence_bound, bench_fig3_lr_mnist,
                            bench_fig5_drl, bench_fig6_rnn_shakespeare,
                            bench_table1_channels)

    bench_table1_channels.run()                                  # Table 1
    bench_convergence_bound.run()                                # Thm 1
    bench_compressor_throughput.run(sizes=(65_536,))             # kernels
    bench_fig3_lr_mnist.run(model="lr", rounds=100, n_train=2000)   # Fig 3
    bench_fig3_lr_mnist.run(model="cnn", rounds=40, n_train=1500)   # Fig 4
    bench_fig5_drl.run(rounds=120)                               # Fig 5
    bench_fig6_rnn_shakespeare.run(rounds=30)                    # Fig 6


if __name__ == '__main__':
    main()
