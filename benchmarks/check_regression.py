"""Bench regression gate: fail CI when simulator throughput slows down.

Two gates, each naming the metric and file that tripped:

* **engine gate** -- the batched-engine ``device_steps_per_s`` rows of a
  freshly generated BENCH_sim.json vs the committed BENCH_baseline.json,
  keyed by (mode, engine, M);
* **task gate** -- the per-task ``device_steps_per_s`` rows of
  BENCH_tasks.json vs the committed BENCH_tasks_baseline.json, keyed by
  (task, engine, M).  cnn_mnist ran at ~3.4 device-steps/s in the smoke
  budget before the §10 hot-path work, one silent regression away from
  unusable, which is why tasks get their own gate;
* **population gate** -- the per-EF-store rows of BENCH_population.json vs
  BENCH_population_baseline.json, keyed by ef_store: ``ef_bytes_vs_dense``
  must not grow past baseline * (1 + tolerance) (the compressed stores'
  whole point is the memory ratio) and ``final_accuracy`` must not drop
  more than ``tolerance`` absolute.  Throughput is deliberately not gated
  here -- the population bench is dominated by host gather/scatter, too
  noisy at smoke budgets.

Exits nonzero when any matching row regresses more than ``--tolerance``
(default 30%).  Rows present on only one side are reported but never fail
the gate (new sweeps should not need a baseline update to land), and
faster-than-baseline rows print so improvements are visible in the CI log.
A missing tasks baseline file skips the task gate with a note (the engine
gate still runs).

The committed baselines were measured on a 2-core container -- slower than
the CI runners -- so the gates only trip on real order-of-magnitude
regressions (a lost jit, an accidental O(M) host loop), not runner jitter.
Refresh both (the recipe also lives in README.md's benchmarking section):

    python -m benchmarks.run --smoke
    cp BENCH_sim.json BENCH_baseline.json
    cp BENCH_tasks.json BENCH_tasks_baseline.json
    cp BENCH_population.json BENCH_population_baseline.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _gate(base_rows: dict, current: dict, tolerance: float, key_of,
          row_filter, label: str) -> list[str]:
    """Generic throughput gate over ``device_steps_per_s`` rows; returns
    failure strings naming the metric, key and file that tripped."""
    seen, failures = set(), []
    for r in current["rows"]:
        if not row_filter(r):
            continue
        key = key_of(r)
        seen.add(key)
        b = base_rows.get(key)
        if b is None:
            print(f"  new row (no baseline): {key}  "
                  f"{r['device_steps_per_s']:.1f} device-steps/s")
            continue
        floor = b["device_steps_per_s"] * (1.0 - tolerance)
        ratio = r["device_steps_per_s"] / b["device_steps_per_s"]
        verdict = "ok" if r["device_steps_per_s"] >= floor else "REGRESSED"
        print(f"  {verdict:>9}: {key}  baseline "
              f"{b['device_steps_per_s']:.1f} -> current "
              f"{r['device_steps_per_s']:.1f} device-steps/s  "
              f"({ratio:.2f}x, floor {floor:.1f})")
        if verdict == "REGRESSED":
            failures.append(f"{label} device_steps_per_s {key}: "
                            f"{ratio:.2f}x of baseline")
    for key in set(base_rows) - seen:
        if row_filter(base_rows[key]):
            print(f"  baseline row missing from current run: {key}")
    return failures


def check(baseline: dict, current: dict, tolerance: float,
          engines: tuple[str, ...] = ("batched",)) -> list[str]:
    """Engine gate: (mode, engine, M)-keyed rows of BENCH_sim.json."""
    base_rows = {(r["mode"], r["engine"], r["m_devices"]): r
                 for r in baseline["rows"]}
    return _gate(base_rows, current, tolerance,
                 key_of=lambda r: (r["mode"], r["engine"], r["m_devices"]),
                 row_filter=lambda r: r["engine"] in engines,
                 label="BENCH_sim.json")


def check_tasks(baseline: dict, current: dict, tolerance: float
                ) -> list[str]:
    """Task gate: (task, engine, M)-keyed rows of BENCH_tasks.json."""
    base_rows = {(r["task"], r["engine"], r["m_devices"]): r
                 for r in baseline["rows"]}
    return _gate(base_rows, current, tolerance,
                 key_of=lambda r: (r["task"], r["engine"], r["m_devices"]),
                 row_filter=lambda r: True,
                 label="BENCH_tasks.json")


def check_population(baseline: dict, current: dict, tolerance: float
                     ) -> list[str]:
    """Population gate: ef_bytes_vs_dense ratio + final_accuracy per
    ef_store row of BENCH_population.json.  Prints every row with its
    verdict so a trip names the exact store and metric."""
    base_rows = {r["ef_store"]: r for r in baseline["rows"]}
    failures = []
    for r in current["rows"]:
        key = r["ef_store"]
        b = base_rows.get(key)
        if b is None:
            print(f"  new row (no baseline): ef_store={key}")
            continue
        ceil_ratio = b["ef_bytes_vs_dense"] * (1.0 + tolerance)
        acc_floor = b["final_accuracy"] - tolerance
        bad_bytes = r["ef_bytes_vs_dense"] > ceil_ratio + 1e-12
        bad_acc = r["final_accuracy"] < acc_floor
        verdict = "REGRESSED" if (bad_bytes or bad_acc) else "ok"
        print(f"  {verdict:>9}: ef_store={key}  bytes_vs_dense "
              f"{b['ef_bytes_vs_dense']:.4f} -> {r['ef_bytes_vs_dense']:.4f}"
              f" (ceiling {ceil_ratio:.4f})  accuracy "
              f"{b['final_accuracy']:.4f} -> {r['final_accuracy']:.4f}"
              f" (floor {acc_floor:.4f})")
        if bad_bytes:
            failures.append(f"BENCH_population.json ef_bytes_vs_dense "
                            f"ef_store={key}: {r['ef_bytes_vs_dense']:.4f} "
                            f"> ceiling {ceil_ratio:.4f}")
        if bad_acc:
            failures.append(f"BENCH_population.json final_accuracy "
                            f"ef_store={key}: {r['final_accuracy']:.4f} "
                            f"< floor {acc_floor:.4f}")
    for key in set(base_rows) - {r["ef_store"] for r in current["rows"]}:
        print(f"  baseline row missing from current run: ef_store={key}")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--current", default="BENCH_sim.json")
    ap.add_argument("--tasks-baseline", default="BENCH_tasks_baseline.json")
    ap.add_argument("--tasks-current", default="BENCH_tasks.json")
    ap.add_argument("--population-baseline",
                    default="BENCH_population_baseline.json")
    ap.add_argument("--population-current", default="BENCH_population.json")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed fractional drop in device_steps_per_s")
    args = ap.parse_args()
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)
    print(f"bench regression gate: tolerance {args.tolerance:.0%} "
          f"({args.baseline} vs {args.current})")
    failures = check(baseline, current, args.tolerance)
    if os.path.exists(args.tasks_baseline) and \
            os.path.exists(args.tasks_current):
        with open(args.tasks_baseline) as f:
            tasks_baseline = json.load(f)
        with open(args.tasks_current) as f:
            tasks_current = json.load(f)
        print(f"per-task gate: tolerance {args.tolerance:.0%} "
              f"({args.tasks_baseline} vs {args.tasks_current})")
        failures += check_tasks(tasks_baseline, tasks_current,
                                args.tolerance)
    else:
        print(f"per-task gate skipped: {args.tasks_baseline} or "
              f"{args.tasks_current} not found")
    if os.path.exists(args.population_baseline) and \
            os.path.exists(args.population_current):
        with open(args.population_baseline) as f:
            pop_baseline = json.load(f)
        with open(args.population_current) as f:
            pop_current = json.load(f)
        print(f"population gate: tolerance {args.tolerance:.0%} "
              f"({args.population_baseline} vs {args.population_current})")
        failures += check_population(pop_baseline, pop_current,
                                     args.tolerance)
    else:
        print(f"population gate skipped: {args.population_baseline} or "
              f"{args.population_current} not found")
    if failures:
        print("bench regression gate FAILED:")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print("bench regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
