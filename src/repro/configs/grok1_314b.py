"""Grok-1-314B [hf:xai-org/grok-1] -- MoE 8 experts top-2, GQA kv=8.

Systems notes: at 314B params the optimizer is SGD-momentum (bf16 moment)
instead of AdamW so that state fits 16 GB/chip HBM on the 256-chip pod
(params 2.45 GB + grads 2.45 + moment 2.45 per chip when FSDP-sharded);
with AdamW (f32 m,v) the dry-run memory analysis exceeds HBM.  Recorded in
EXPERIMENTS.md §Dry-run."""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b", arch_type="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32_768, vocab_size=131_072,
    n_experts=8, experts_per_tok=2, d_expert=32_768,
    mlp="geglu", norm="rmsnorm",   # gated experts: 3 matmuls -> ~314B total
    fsdp=True, optimizer="sgdm",
    source="hf:xai-org/grok-1",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, name="grok1-smoke", n_layers=2, d_model=128, n_heads=4,
        n_kv_heads=2, d_ff=256, d_expert=256, vocab_size=512,
        n_experts=4, experts_per_tok=2, fsdp=False, remat=False,
        attn_q_chunk=64)
