"""Task zoo (repro.models.paper_models.TASKS): the paper's three engine
workloads -- LR and CNN on MNIST, char-RNN on Shakespeare -- as
first-class, engine-equivalent citizens.  (The fourth registry entry,
qwen2_100m, is the sharded 100M token stack: its ladder lives in
tests/test_lgc_step.py.)

Every registry task must run through the loop, batched and sharded engines
and produce the same History: allclose for loop-vs-batched (float reduction
order differs), BIT-identical for batched-vs-sharded with the gather server
reduce -- under both a static and a dynamic (gilbert_flaky) scenario, at
every mesh size the process can build (the test-sharded CI lane forces 8
host devices, so the {1, 8} matrix of the acceptance criteria runs there).

Plus: the Shakespeare train/eval-leakage fix (the held-out batch is drawn
from a disjoint character-stream tail), deterministic per-device sharding,
and the ragged-shard stacking properties of the batched engine's
``_stack_device_data`` (padding rows are never sampled)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FLConfig, LGCSimulator, FixedController, run_baseline
from repro.core.fl import TAG_BATCH, stream_key
from repro.core.fl_batched import _stack_device_data
from repro.data import char_shards, partition_iid, split_stream
from repro.launch.mesh import make_host_mesh
from repro.models.paper_models import (ENGINE_TASKS, TASKS,
                                       make_shakespeare_task, make_task)

from _hypothesis_compat import given, settings, st  # hypothesis or fallback

N_DEV = len(jax.devices())
SHARD_COUNTS = sorted({1, N_DEV})        # >= 2 mesh sizes when devices allow
M = 8                                    # divides every power-of-two mesh
SCENARIO_NAMES = ("static", "gilbert_flaky")

_TASKS: dict = {}
_BATCHED: dict = {}


def _cfg(scen: str) -> FLConfig:
    return FLConfig(rounds=10, eval_every=5, batch_size=16, scenario=scen)


def _task(name: str, scen: str):
    key = (name, scen)
    if key not in _TASKS:
        kw = dict(n_train=640) if name.endswith("mnist") else \
            dict(n_train=640, seq=24)
        _TASKS[key] = make_task(name, m_devices=M, scenario=scen, **kw)
    return _TASKS[key]


def _batched_hist(name: str, scen: str):
    key = (name, scen)
    if key not in _BATCHED:
        _BATCHED[key] = run_baseline(_task(name, scen), _cfg(scen), "lgc",
                                     h=4, engine="batched")
    return _BATCHED[key]


class TestTaskEngineEquivalence:
    """loop ~ batched == sharded for every registry task x scenario."""

    @pytest.mark.parametrize("scen", SCENARIO_NAMES)
    @pytest.mark.parametrize("name", ENGINE_TASKS)
    def test_loop_matches_batched(self, name, scen):
        h_loop = run_baseline(_task(name, scen), _cfg(scen), "lgc", h=4,
                              engine="loop")
        h_bat = _batched_hist(name, scen)
        assert h_loop.step == h_bat.step
        np.testing.assert_allclose(h_bat.loss, h_loop.loss, atol=1e-4)
        np.testing.assert_allclose(h_bat.accuracy, h_loop.accuracy,
                                   atol=1e-4)
        np.testing.assert_allclose(h_bat.uplink_mb, h_loop.uplink_mb,
                                   atol=1e-4)
        np.testing.assert_allclose(h_bat.energy_j, h_loop.energy_j,
                                   rtol=1e-5)

    @pytest.mark.parametrize("n_shards", SHARD_COUNTS)
    @pytest.mark.parametrize("scen", SCENARIO_NAMES)
    @pytest.mark.parametrize("name", ENGINE_TASKS)
    def test_sharded_bit_identical(self, name, scen, n_shards):
        """Gather-mode History carries the exact same floats at every mesh
        size -- NHWC conv grads and int32-sequence GRU grads included (the
        per-device vmapped float math must stay batch-shape stable; see
        docs/ARCHITECTURE.md §4)."""
        h_sh = run_baseline(_task(name, scen), _cfg(scen), "lgc", h=4,
                            engine="sharded", mesh=make_host_mesh(n_shards))
        assert h_sh.asdict() == _batched_hist(name, scen).asdict()

    @pytest.mark.parametrize("name", ENGINE_TASKS)
    def test_tasks_learn(self, name):
        """Sanity floor: a short static run must reduce the loss -- the
        zoo exists to measure learning, not just to not crash."""
        h = _batched_hist(name, "static")
        assert np.isfinite(h.loss[-1])
        assert h.loss[-1] < h.loss[0]


class TestTaskRegistry:
    def test_registry_names_are_consistent(self):
        for name, spec in TASKS.items():
            assert spec.name == name
        assert set(TASKS) == {"lr_mnist", "cnn_mnist", "rnn_shakespeare",
                              "qwen2_100m"}
        # the engine-equivalence ladder runs over the FLTask zoo only; the
        # token stack has its own rung (tests/test_lgc_step.py)
        assert set(ENGINE_TASKS) == {"lr_mnist", "cnn_mnist",
                                     "rnn_shakespeare"}

    def test_unknown_name_raises(self):
        with pytest.raises(ValueError, match="unknown task"):
            make_task("resnet_imagenet")

    def test_make_task_builds_m_shards(self):
        for name in ENGINE_TASKS:
            task = _task(name, "static")
            assert len(task.device_data) == M
            for x, y in task.device_data:
                assert x.shape[0] == y.shape[0] > 0

    def test_scenario_overrides_partition(self):
        """dirichlet0.3's partition rides into the task factory: shard label
        (region) marginals must be skewed relative to the IID default."""
        from repro.data import skew_score
        iid = make_task("rnn_shakespeare", m_devices=6, n_train=600, seq=24,
                        scenario="static")
        skew = make_task("rnn_shakespeare", m_devices=6, n_train=600, seq=24,
                         scenario="dirichlet0.3")
        assert len(iid.device_data) == len(skew.device_data) == 6
        # region labels are not carried in the shards, so compare sizes: the
        # Dirichlet partition concentrates regions and unbalances devices
        sizes = sorted(y.shape[0] for _, y in skew.device_data)
        assert sizes[0] < sizes[-1]
        assert skew_score is not None  # imported API stays available

    def test_task_dtypes(self):
        x, y = _task("cnn_mnist", "static").device_data[0]
        assert x.dtype == np.float32 and x.shape[1:] == (28, 28, 1)
        xs, ys = _task("rnn_shakespeare", "static").device_data[0]
        assert xs.dtype == np.int32 and ys.dtype == np.int32
        assert xs.shape[1] == 24


class TestShakespeareTask:
    def test_eval_split_is_disjoint(self):
        """The held-out batch must come from a character-stream tail no
        device shard can touch.  With an arange stream, token values encode
        stream positions, so disjointness is directly observable."""
        stream = np.arange(5000, dtype=np.int32)
        train, test = split_stream(stream, test_frac=0.2)
        assert train.size + test.size == stream.size
        shards, (xte, yte) = char_shards(
            stream, 4, seq=16, n_train=200, n_eval=64, seed=3,
            partition_fn=lambda x, y, m, seed: partition_iid(x, y, m, seed),
            test_frac=0.2)
        cut = train.size
        for x, y in shards:
            assert x.max() < cut and y.max() < cut
        assert xte.min() >= cut and yte.min() >= cut

    def test_real_task_eval_uses_heldout(self):
        task = make_shakespeare_task(m_devices=3, seq=24, n_train=300,
                                     n_eval=64)
        xte, yte = task.eval_data
        assert xte.shape == (64, 24) and yte.shape == (64, 24)

    def test_deterministic_per_seed(self):
        a = make_shakespeare_task(m_devices=4, seq=24, n_train=400, seed=9)
        b = make_shakespeare_task(m_devices=4, seq=24, n_train=400, seed=9)
        for (xa, ya), (xb, yb) in zip(a.device_data, b.device_data):
            np.testing.assert_array_equal(xa, xb)
            np.testing.assert_array_equal(ya, yb)
        np.testing.assert_array_equal(a.eval_data[0], b.eval_data[0])

    def test_default_partition_is_exact(self):
        """The registry default (Dirichlet over regions) must use every
        requested window exactly once -- the legacy 'noniid' partitioner
        subsamples, which would silently shrink the training set."""
        t = make_shakespeare_task(m_devices=5, seq=24, n_train=500)
        assert sum(y.shape[0] for _, y in t.device_data) == 500

    def test_partition_quantity_skew_unbalances_shards(self):
        t = make_shakespeare_task(m_devices=6, seq=24, n_train=600,
                                  partition="quantity", alpha=0.1)
        sizes = [y.shape[0] for _, y in t.device_data]
        assert max(sizes) > 2 * min(sizes)
        assert sum(sizes) == 600                   # exact partition

    def test_targets_are_shifted_inputs(self):
        t = make_shakespeare_task(m_devices=2, seq=24, n_train=100)
        x, y = t.device_data[0]
        np.testing.assert_array_equal(x[:, 1:], y[:, :-1])


class TestStackDeviceData:
    """Ragged per-device shards -> one (M, Nmax, ...) stacked pytree whose
    zero-padding rows are never sampled by the window's minibatch gather."""

    @settings(max_examples=15, deadline=None)
    @given(st.lists(st.integers(1, 37), min_size=2, max_size=6),
           st.integers(0, 1000))
    def test_padding_never_sampled(self, sizes, t):
        """Real rows are strictly positive int32; padding is zero.  Gathering
        with the engine's own key scheme (stream_key TAG_BATCH, randint
        bounded by the true row count) must only ever see real rows."""
        shards = [(np.full((n, 5), 7, np.int32),
                   np.full((n,), 7, np.int32)) for n in sizes]
        data, n_dev = _stack_device_data(shards)
        xs, ys = data
        assert xs.shape == (len(sizes), max(sizes), 5)
        base = jax.random.PRNGKey(0)
        for m, n in enumerate(sizes):
            key = stream_key(base, TAG_BATCH, t, m)
            idx = jax.random.randint(key, (64,), 0, n_dev[m])
            assert int(jnp.min(xs[m][idx])) == 7
            assert int(jnp.min(ys[m][idx])) == 7
            # and the padding really is inert zeros past the true count
            assert int(jnp.sum(jnp.abs(xs[m, n:]))) == 0

    @settings(max_examples=10, deadline=None)
    @given(st.lists(st.integers(1, 20), min_size=2, max_size=5))
    def test_roundtrip(self, sizes):
        rng = np.random.default_rng(1)
        shards = [(rng.integers(0, 50, (n, 3)).astype(np.int32),
                   rng.integers(0, 9, (n,)).astype(np.int32))
                  for n in sizes]
        data, n_dev = _stack_device_data(shards)
        xs, ys = data
        assert list(np.asarray(n_dev)) == sizes
        for i, (x, y) in enumerate(shards):
            np.testing.assert_array_equal(np.asarray(xs[i, : x.shape[0]]), x)
            np.testing.assert_array_equal(np.asarray(ys[i, : y.shape[0]]), y)

    def test_ragged_int32_engine_equivalence(self):
        """End-to-end proof that padding stays inert: a quantity-skewed
        (highly ragged) char-RNN task must produce identical trajectories
        from the loop engine (which never sees padding) and the batched
        engine (which stacks + pads)."""
        task = make_shakespeare_task(m_devices=4, seq=16, n_train=240,
                                     partition="quantity", alpha=0.1)
        sizes = [y.shape[0] for _, y in task.device_data]
        assert max(sizes) > 2 * min(sizes)     # the stacking really is ragged
        cfg = FLConfig(rounds=8, eval_every=4, batch_size=8)
        ctrls = lambda: [FixedController(2 + m % 3, [200, 300, 400])
                         for m in range(4)]
        h_loop = LGCSimulator(task, cfg, ctrls(), mode="lgc",
                              engine="loop").run()
        h_bat = LGCSimulator(task, cfg, ctrls(), mode="lgc",
                             engine="batched").run()
        np.testing.assert_allclose(h_bat.loss, h_loop.loss, atol=1e-4)
        np.testing.assert_allclose(h_bat.uplink_mb, h_loop.uplink_mb,
                                   atol=1e-4)
