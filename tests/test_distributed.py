"""Distributed runtime tests (subprocess with 8 host devices): sharding
specs, sync/LGC train steps, serve step, and a reduced-mesh dry-run."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(py: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(py)],
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


class TestShardingRules:
    def test_param_specs_cover_all_leaves(self):
        out = _run("""
            import jax
            from repro.configs import get_smoke_config, list_archs
            from repro.launch import compat
            from repro.launch.mesh import make_host_mesh, set_mesh
            from repro.launch import sharding_rules as rules
            from repro.models import transformer as tf
            mesh = make_host_mesh(8, model=2)
            for arch in list_archs():
                cfg = get_smoke_config(arch)
                params = jax.eval_shape(
                    lambda k: tf.init_params(cfg, k),
                    jax.ShapeDtypeStruct((2,), jax.numpy.uint32))
                specs = rules.param_specs(cfg, params, mesh)
                n1 = len(jax.tree_util.tree_leaves(params))
                n2 = len(jax.tree_util.tree_leaves(
                    specs, is_leaf=lambda x: isinstance(
                        x, jax.sharding.PartitionSpec)))
                assert n1 == n2, (arch, n1, n2)
            print("ok")
        """)
        assert "ok" in out

    def test_full_config_specs_divisible_on_production_mesh(self):
        """Every full-size param must be divisible by its spec'd axes."""
        out = _run("""
            import jax
            from repro.configs import get_config, list_archs
            from repro.launch.mesh import make_production_mesh
            from repro.launch import sharding_rules as rules
            from repro.models import transformer as tf
            mesh = make_production_mesh()
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            for arch in list_archs():
                cfg = get_config(arch)
                params = jax.eval_shape(
                    lambda k: tf.init_params(cfg, k),
                    jax.ShapeDtypeStruct((2,), jax.numpy.uint32))
                specs = rules.param_specs(cfg, params, mesh)
                flat_p = jax.tree_util.tree_leaves_with_path(params)
                flat_s = jax.tree_util.tree_leaves(
                    specs, is_leaf=lambda x: isinstance(
                        x, jax.sharding.PartitionSpec))
                for (path, leaf), spec in zip(flat_p, flat_s):
                    for dim, ax in zip(leaf.shape, spec):
                        if ax is None: continue
                        n = sizes[ax] if isinstance(ax, str) else 1
                        assert dim % n == 0, (arch, path, leaf.shape, spec)
            print("ok")
        """, devices=256)
        assert "ok" in out


class TestTrainSteps:
    def test_sync_step_loss_decreases(self):
        out = _run("""
            import jax, jax.numpy as jnp
            from repro.configs import get_smoke_config
            from repro.data.tokens import TokenPipeline
            from repro.launch import compat
            from repro.launch.mesh import make_host_mesh, set_mesh
            from repro.launch import sharding_rules as rules
            from repro.launch.steps import make_sync_train_step
            from repro.models import transformer as tf
            from repro.optim.optimizers import OptimizerConfig, get_optimizer
            cfg = get_smoke_config("qwen2-1.5b")
            mesh = make_host_mesh(8, model=2)
            set_mesh(mesh)
            params = tf.init_params(cfg, jax.random.PRNGKey(0))
            opt_init, _ = get_optimizer("adamw", OptimizerConfig(lr=1e-3))
            opt = opt_init(params)
            pipe = TokenPipeline(cfg.vocab_size, 64, 16)
            step = make_sync_train_step(cfg, accum_steps=2,
                                        opt_cfg=OptimizerConfig(lr=1e-3))
            pspecs = rules.param_specs(cfg, params, mesh)
            params = rules.place(params, pspecs, mesh)
            opt = rules.place(opt, rules.opt_state_specs(pspecs, opt), mesh)
            x, y = pipe.next_batch()
            batch = {"tokens": jnp.asarray(x), "labels": jnp.asarray(y)}
            bspecs = rules.batch_specs(cfg, batch, mesh)
            from jax.sharding import PartitionSpec as P
            ospecs = rules.opt_state_specs(pspecs, opt)
            jstep = jax.jit(step,
                            in_shardings=compat.shardings(
                                mesh, (pspecs, ospecs, bspecs)),
                            out_shardings=compat.shardings(
                                mesh, (pspecs, ospecs, P())))
            losses = []
            for i in range(20):
                x, y = pipe.next_batch()
                params, opt, l = jstep(params, opt,
                                       {"tokens": jnp.asarray(x),
                                        "labels": jnp.asarray(y)})
                losses.append(float(l))
            print("first", losses[0], "last", losses[-1])
            assert losses[-1] < losses[0]
        """)
        assert "first" in out

    @pytest.mark.parametrize("aggregate", ["dense_masked", "sparse_gather",
                                           "bucket_sparse", "none"])
    def test_lgc_step_runs_and_learns(self, aggregate):
        out = _run(f"""
            import jax, jax.numpy as jnp
            from repro.configs import get_smoke_config
            from repro.data.tokens import TokenPipeline
            from repro.launch import compat
            from repro.launch.mesh import make_host_mesh, set_mesh
            from repro.launch import sharding_rules as rules
            from repro.launch.steps import (LGCStepConfig, init_ef_tree,
                                            make_lgc_train_step)
            from repro.models import transformer as tf
            cfg = get_smoke_config("qwen2-1.5b")
            mesh = make_host_mesh(8, model=1)
            set_mesh(mesh)
            params = tf.init_params(cfg, jax.random.PRNGKey(0))
            lgc = LGCStepConfig(local_steps=2, local_lr=5e-3,
                                sparsity=(0.02, 0.03),
                                aggregate="{aggregate}")
            pipe = TokenPipeline(cfg.vocab_size, 64, 16)
            x, y = pipe.next_batch()
            batch = {{"tokens": jnp.asarray(x), "labels": jnp.asarray(y)}}
            bspecs = rules.batch_specs(cfg, batch, mesh)
            pspecs = rules.param_specs(cfg, params, mesh)
            params = rules.place(params, pspecs, mesh)
            from jax.sharding import PartitionSpec as P
            especs = rules.ef_specs(pspecs, "data")
            step = jax.jit(make_lgc_train_step(cfg, mesh, lgc, bspecs),
                           in_shardings=compat.shardings(
                               mesh, (pspecs, especs, bspecs)),
                           out_shardings=compat.shardings(
                               mesh, (pspecs, especs, P())))
            ef = rules.place(init_ef_tree(params, 8), especs, mesh)
            losses = []
            for i in range(15):
                x, y = pipe.next_batch()
                params, ef, l = step(params, ef,
                                     {{"tokens": jnp.asarray(x),
                                       "labels": jnp.asarray(y)}})
                losses.append(float(l))
            print("first", losses[0], "last", losses[-1])
            assert losses[-1] < losses[0]
            # error memory is active for compressed modes
            import numpy as np
            efn = sum(float(jnp.sum(jnp.abs(e))) for e in
                      jax.tree_util.tree_leaves(ef))
            print("ef mass", efn)
            if "{aggregate}" != "none":
                assert efn > 0
        """)
        assert "first" in out


class TestServing:
    def test_serve_step_sharded(self):
        out = _run("""
            import jax, jax.numpy as jnp
            from repro.configs import get_smoke_config
            from repro.launch import compat
            from repro.launch.mesh import make_host_mesh, set_mesh
            from repro.launch import sharding_rules as rules
            from repro.launch.steps import make_serve_step
            from repro.models import transformer as tf
            cfg = get_smoke_config("zamba2-1.2b")
            mesh = make_host_mesh(8, model=2)
            set_mesh(mesh)
            params = tf.init_params(cfg, jax.random.PRNGKey(0))
            b = 8
            cache = tf.init_cache(cfg, b, 64)
            tok = jnp.ones((b, 1), jnp.int32)
            cspecs = rules.cache_specs(cfg, cache, mesh)
            pspecs = rules.param_specs(cfg, params, mesh)
            tspec = rules.batch_specs(cfg, {"token": tok}, mesh)["token"]
            params = rules.place(params, pspecs, mesh)
            cache = rules.place(cache, cspecs, mesh)
            tok = rules.place(tok, tspec, mesh)
            step = jax.jit(make_serve_step(cfg),
                           in_shardings=compat.shardings(mesh, (pspecs, tspec, cspecs)),
                           out_shardings=compat.shardings(mesh, (tspec, cspecs)))
            for i in range(4):
                tok, cache = step(params, tok, cache)
            assert int(cache["pos"]) == 4
            print("ok", tok.shape)
        """)
        assert "ok" in out


class TestDryRunIntegration:
    def test_dryrun_cli_smoke_mesh(self):
        """The real dryrun module, 512 fake devices, one cheap pair."""
        env = dict(os.environ, PYTHONPATH=SRC)
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch",
             "whisper-small", "--shape", "decode_32k"],
            capture_output=True, text=True, env=env, timeout=1200,
            cwd=os.path.dirname(SRC))
        assert out.returncode == 0, out.stderr[-3000:]
        assert "all dry-runs compiled OK" in out.stdout
