"""Version-compat shims over the jax sharding API drift (0.4.x vs >= 0.5).

The launch stack targets the modern explicit-sharding surface
(``jax.make_mesh(axis_types=...)``, ``jax.set_mesh``, ``jax.shard_map`` with
``axis_names``/``check_vma``), but the pinned CI container ships jax 0.4.37
where those spell ``jax.make_mesh`` without axis types, the mesh
resource-env context, and ``jax.experimental.shard_map`` with
``auto``/``check_rep``.  Everything here is a thin feature-detected
dispatch -- no behaviour change on new jax.

The sharded FL engine (:class:`repro.core.fl_batched.ShardedEngine`) uses
the fully-manual :func:`shard_map` path (``axis_names=None``), which maps to
``auto=frozenset()`` on 0.4.x -- partial-auto is never required.  CI runs a
{pinned, latest} jax matrix so drift in these shims surfaces the day a new
jax releases, not when the pin moves.
"""
from __future__ import annotations

import os
from typing import Sequence

import jax


def ensure_fast_cpu_runtime() -> bool:
    """Opt XLA:CPU out of the thunk runtime on the jaxlib 0.4.3x line.

    The thunk runtime (default since jaxlib 0.4.32) executes ``while`` loop
    bodies through a concurrent task scheduler whose dispatch overhead
    dwarfs the actual compute on small-core hosts: the cnn_mnist sync
    window (a 4-step ``lax.scan`` over vmapped conv grads) measures 26.2 s
    per window on a 1-core container against 0.70 s with
    ``--xla_cpu_use_thunk_runtime=false`` -- a 37x gap that made the CNN /
    GRU tasks look compute-bound when they were scheduler-bound
    (docs/ARCHITECTURE.md §10).

    Appends the flag to ``XLA_FLAGS`` (idempotently) so it takes effect at
    the first backend initialisation.  Gated to jaxlib versions that still
    ship the legacy runtime ([0.4.32, 0.5)): unknown XLA flags are a hard
    startup error, so newer jaxlibs -- where the legacy runtime was removed
    -- must not see it.  Set ``REPRO_XLA_THUNK_RUNTIME=1`` to keep the
    thunk runtime (e.g. to benchmark it).  Returns True when the flag is
    (already) applied.  Best-effort: if the backend is already initialised
    the env change cannot take effect for this process.
    """
    flag = "--xla_cpu_use_thunk_runtime=false"
    if flag in os.environ.get("XLA_FLAGS", ""):
        return True
    if os.environ.get("REPRO_XLA_THUNK_RUNTIME") == "1":
        return False
    try:
        import jaxlib
        ver = tuple(int(p) for p in jaxlib.__version__.split(".")[:3])
    except Exception:
        return False
    if not ((0, 4, 32) <= ver < (0, 5, 0)):
        return False
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " " + flag).strip()
    return True


def force_host_device_count(n: int) -> None:
    """Make the CPU backend expose ``n`` virtual devices (a host mesh).

    Rewrites ``XLA_FLAGS`` with ``--xla_force_host_platform_device_count=n``;
    any pre-existing occurrence of the flag is dropped first, because XLA
    honours the LAST occurrence -- naively prepending would let an inherited
    environment value (e.g. the test-sharded CI lane's =8) silently win.
    Must run before the first jax backend initialisation in the process,
    which is why the mesh-scaling bench workers apply it in a fresh
    subprocess per device count.
    """
    kept = [f for f in os.environ.get("XLA_FLAGS", "").split()
            if not f.startswith("--xla_force_host_platform_device_count=")]
    os.environ["XLA_FLAGS"] = " ".join(
        kept + [f"--xla_force_host_platform_device_count={n}"])
    # callers invoke this before their first backend init (fresh worker
    # processes), which is also the last safe moment for the CPU runtime
    # flag -- piggyback so subprocess workers that import jax before
    # repro.core still get the fast runtime
    ensure_fast_cpu_runtime()


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> "jax.sharding.Mesh":
    """``jax.make_mesh`` with Auto axis types when the API supports them."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def set_mesh(mesh: "jax.sharding.Mesh") -> "jax.sharding.Mesh":
    """Install ``mesh`` as the ambient mesh for subsequent jit/pjit calls.

    New jax: ``jax.set_mesh``.  Old jax: enter the legacy resource-env
    context (and leave it open -- callers use this once at program setup,
    matching ``jax.set_mesh`` semantics, not as a scoped context).
    """
    if hasattr(jax, "set_mesh"):
        jax.set_mesh(mesh)
    else:
        mesh.__enter__()
    return mesh


def shardings(mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree for jit in/out_shardings.

    Every jax version accepts Sharding objects; 0.4.x ``jax.jit`` accepts
    *only* those (bare PartitionSpecs raise), so call sites route specs
    through this before handing them to jit.
    """
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec))


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None):
    """``jax.shard_map`` manual over ``axis_names`` only (auto elsewhere).

    Old jax spells partial-manual as ``auto=<complement>`` on
    ``jax.experimental.shard_map.shard_map``; replica/vma checking is
    disabled on both paths (the LGC step's gather patterns trip it).
    """
    if hasattr(jax, "shard_map"):
        kwargs = {"check_vma": False}
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - set(axis_names or mesh.axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False, auto=auto)
