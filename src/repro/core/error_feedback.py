"""Error-feedback state for LGC (paper Algorithm 1, lines 8-11).

The device-side update at a synchronization step t in I_m is

    u_m  = e_m + w_m - w_hat_m^{t+1/2}          (net progress + carried error)
    g_m  = LGC_k(u_m)                           (compressed update, uploaded)
    e_m' = u_m - g_m                            (error kept for next round)

Between synchronizations e_m is untouched (Algorithm 1 line 17).

The invariant is  u == g + e'  exactly (floating-point exact, since g is a
masked copy) -- pinned by tests/test_compressor.py::TestErrorFeedback::
test_identity_u_eq_g_plus_e; bounded EF growth under burst loss/dropout by
tests/test_scenarios.py::TestErrorFeedbackUnderDropout.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from .compressor import LGCCompressor

Array = jax.Array


class EFState(NamedTuple):
    """Flat error memory, one vector per FL device (or per shard)."""
    e: Array  # (D,) float32


def init_ef(d: int) -> EFState:
    return EFState(e=jnp.zeros((d,), jnp.float32))


def ef_compress(state: EFState, delta: Array, compressor: LGCCompressor,
                received: Sequence[bool] | None = None
                ) -> tuple[Array, EFState]:
    """One error-compensated compression step.

    Args:
      state: current error memory e_m.
      delta: net progress  w_m - w_hat_m^{t+1/2}  (i.e. sum of local LR*grads).
      compressor: the LGC_k operator for this round.
      received: optional per-channel delivery mask (channel failure model).

    Returns (g, new_state) where g is the compressed update actually applied
    at the server and new_state carries u - g_sent.  NOTE: when a channel
    drops a layer, that layer's mass stays in the error memory (it was not
    delivered), which is exactly the graceful-degradation property of layered
    coding: the information is retransmitted (with error feedback) later.
    """
    u = state.e + delta
    g_sent = compressor(u, received)          # what the server receives
    g_all = compressor(u, None)               # what the device selected
    # Mass selected but dropped by a channel goes back into the memory too:
    e_new = u - g_sent if received is not None else u - g_all
    del g_all
    return g_sent, EFState(e=e_new)
