"""Version-compat shims over the jax sharding API drift (0.4.x vs >= 0.5).

The launch stack targets the modern explicit-sharding surface
(``jax.make_mesh(axis_types=...)``, ``jax.set_mesh``, ``jax.shard_map`` with
``axis_names``/``check_vma``), but the pinned CI container ships jax 0.4.37
where those spell ``jax.make_mesh`` without axis types, the mesh
resource-env context, and ``jax.experimental.shard_map`` with
``auto``/``check_rep``.  Everything here is a thin feature-detected
dispatch -- no behaviour change on new jax.

The sharded FL engine (:class:`repro.core.fl_batched.ShardedEngine`) uses
the fully-manual :func:`shard_map` path (``axis_names=None``), which maps to
``auto=frozenset()`` on 0.4.x -- partial-auto is never required.  CI runs a
{pinned, latest} jax matrix so drift in these shims surfaces the day a new
jax releases, not when the pin moves.
"""
from __future__ import annotations

import os
from typing import Sequence

import jax


def force_host_device_count(n: int) -> None:
    """Make the CPU backend expose ``n`` virtual devices (a host mesh).

    Rewrites ``XLA_FLAGS`` with ``--xla_force_host_platform_device_count=n``;
    any pre-existing occurrence of the flag is dropped first, because XLA
    honours the LAST occurrence -- naively prepending would let an inherited
    environment value (e.g. the test-sharded CI lane's =8) silently win.
    Must run before the first jax backend initialisation in the process,
    which is why the mesh-scaling bench workers apply it in a fresh
    subprocess per device count.
    """
    kept = [f for f in os.environ.get("XLA_FLAGS", "").split()
            if not f.startswith("--xla_force_host_platform_device_count=")]
    os.environ["XLA_FLAGS"] = " ".join(
        kept + [f"--xla_force_host_platform_device_count={n}"])


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> "jax.sharding.Mesh":
    """``jax.make_mesh`` with Auto axis types when the API supports them."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def set_mesh(mesh: "jax.sharding.Mesh") -> "jax.sharding.Mesh":
    """Install ``mesh`` as the ambient mesh for subsequent jit/pjit calls.

    New jax: ``jax.set_mesh``.  Old jax: enter the legacy resource-env
    context (and leave it open -- callers use this once at program setup,
    matching ``jax.set_mesh`` semantics, not as a scoped context).
    """
    if hasattr(jax, "set_mesh"):
        jax.set_mesh(mesh)
    else:
        mesh.__enter__()
    return mesh


def shardings(mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree for jit in/out_shardings.

    Every jax version accepts Sharding objects; 0.4.x ``jax.jit`` accepts
    *only* those (bare PartitionSpecs raise), so call sites route specs
    through this before handing them to jit.
    """
    from jax.sharding import NamedSharding, PartitionSpec
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec))


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None):
    """``jax.shard_map`` manual over ``axis_names`` only (auto elsewhere).

    Old jax spells partial-manual as ``auto=<complement>`` on
    ``jax.experimental.shard_map.shard_map``; replica/vma checking is
    disabled on both paths (the LGC step's gather patterns trip it).
    """
    if hasattr(jax, "shard_map"):
        kwargs = {"check_vma": False}
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - set(axis_names or mesh.axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False, auto=auto)
