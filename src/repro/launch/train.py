"""Training driver: runs real steps on the host devices (CPU here, TPU pod
in production) with the same step functions the dry-run lowers.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 50 --mode lgc --devices 8

``--devices N`` simulates an N-device mesh on the host (set before jax
import); the LGC mode then treats the data axis as N FL devices.
"""
import argparse
import os
import sys


def _parse():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mode", default="sync",
                    choices=["sync", "lgc", "lgc_sparse", "fedavg"])
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--sparsity", default="0.01,0.02,0.02")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    return ap.parse_args()


def main():
    args = _parse()
    if args.devices > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.checkpoint import save_checkpoint
    from repro.configs import get_config, get_smoke_config
    from repro.data.tokens import TokenPipeline
    from repro.launch import sharding_rules as rules
    from repro.launch import compat
    from repro.launch.mesh import make_host_mesh
    from repro.launch.steps import (LGCStepConfig, init_ef_tree,
                                    make_lgc_train_step, make_sync_train_step)
    from repro.models import transformer as tf
    from repro.optim.optimizers import OptimizerConfig, get_optimizer

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh(args.devices, model=args.model_parallel)
    compat.set_mesh(mesh)

    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M mode={args.mode} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch)
    pspecs = rules.param_specs(cfg, params, mesh)
    params = rules.place(params, pspecs, mesh)
    x0, y0 = pipe.next_batch()
    batch0 = {"tokens": jnp.asarray(x0), "labels": jnp.asarray(y0)}
    if cfg.arch_type == "vlm":
        batch0["prefix"] = jnp.zeros((args.batch, cfg.n_prefix_tokens, 1024),
                                     cfg.dtype)
    if cfg.arch_type == "audio":
        batch0["prefix"] = jnp.zeros((args.batch, cfg.encoder_seq,
                                      cfg.d_model), cfg.dtype)
    bspecs = rules.batch_specs(cfg, batch0, mesh)

    losses = []
    if args.mode == "sync":
        opt_init, _ = get_optimizer(cfg.optimizer,
                                    OptimizerConfig(lr=args.lr))
        opt_state = opt_init(params)
        opt_state = rules.place(
            opt_state, rules.opt_state_specs(pspecs, opt_state), mesh)
        step = jax.jit(make_sync_train_step(
            cfg, opt_cfg=OptimizerConfig(lr=args.lr)),
            in_shardings=compat.shardings(mesh, (pspecs, rules.opt_state_specs(pspecs, opt_state),
                          bspecs)),
            donate_argnums=(0, 1))
        state = (params, opt_state)
        for i in range(args.steps):
            x, y = pipe.next_batch()
            batch = dict(batch0, tokens=jnp.asarray(x), labels=jnp.asarray(y))
            params, opt_state, loss = step(*state, batch)
            state = (params, opt_state)
            losses.append(float(loss))
            if i % args.log_every == 0:
                print(f"step {i:5d} loss {losses[-1]:.4f}")
    else:
        lgc = LGCStepConfig(
            local_steps=args.local_steps,
            sparsity=tuple(float(x) for x in args.sparsity.split(",")),
            local_lr=args.lr,
            aggregate={"lgc": "dense_masked", "lgc_sparse": "sparse_gather",
                       "fedavg": "none"}[args.mode])
        from repro.launch.mesh import fl_axis_name
        fl_ax = fl_axis_name(mesh)
        n_fl = dict(zip(mesh.axis_names, mesh.devices.shape))[fl_ax]
        especs = rules.ef_specs(pspecs, fl_ax)
        ef = rules.place(init_ef_tree(params, n_fl), especs, mesh)
        step = jax.jit(make_lgc_train_step(cfg, mesh, lgc, bspecs),
                       in_shardings=compat.shardings(mesh, (pspecs, especs, bspecs)),
                       donate_argnums=(0, 1))
        for i in range(args.steps):
            x, y = pipe.next_batch()
            batch = dict(batch0, tokens=jnp.asarray(x), labels=jnp.asarray(y))
            params, ef, loss = step(params, ef, batch)
            losses.append(float(loss))
            if i % args.log_every == 0:
                print(f"round {i:5d} (H={args.local_steps}) "
                      f"loss {losses[-1]:.4f}")
        if args.ckpt_dir:
            save_checkpoint(args.ckpt_dir, args.steps, params)

    if args.ckpt_dir and args.mode == "sync" and args.ckpt_every:
        save_checkpoint(args.ckpt_dir, args.steps, params)
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    if not (np.isfinite(losses[-1]) and losses[-1] < losses[0]):
        print("WARNING: loss did not decrease", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
