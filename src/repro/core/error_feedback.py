"""Error-feedback state for LGC (paper Algorithm 1, lines 8-11).

The device-side update at a synchronization step t in I_m is

    u_m  = e_m + w_m - w_hat_m^{t+1/2}          (net progress + carried error)
    g_m  = LGC_k(u_m)                           (compressed update, uploaded)
    e_m' = u_m - g_m                            (error kept for next round)

Between synchronizations e_m is untouched (Algorithm 1 line 17).

The invariant is  u == g + e'  exactly (floating-point exact, since g is a
masked copy) -- pinned by tests/test_compressor.py::TestErrorFeedback::
test_identity_u_eq_g_plus_e; bounded EF growth under burst loss/dropout by
tests/test_scenarios.py::TestErrorFeedbackUnderDropout.

Population-scale storage.  At N >= 100k devices the dense (N, D) f32 error
memory is the RAM blocker (ROADMAP item 1), so this module also provides the
pluggable **EF stores** used by :mod:`repro.core.population`: host-resident
(numpy) per-device residual state with a ``gather(ids) -> (M, D) f32`` /
``scatter(ids, ef)`` cohort interface and an exact ``nbytes`` accounting.

* :class:`DenseEFStore` -- (N, D) f32; the lossless reference.
  Gather/scatter roundtrip is bitwise exact --
  tests/test_population.py::TestEFStores::test_dense_roundtrip_exact.
* :class:`Int8EFStore` -- int8 codes + one f32 scale per device
  (``scale = max|e| / 127``, symmetric round-to-nearest).  Per-element
  decode error is <= scale/2 = max|e|/254; total footprint is
  ``N*D + 4N`` bytes, i.e. ~26% of dense for D >= 20 --
  tests/test_population.py::TestEFStores (error bound + byte ratio).
* :class:`ServerEFStore` -- ONE aggregate (D,) residual held server-side
  (devices stay stateless).  ``gather`` broadcasts it to every cohort row;
  ``scatter`` keeps the cohort mean, which realizes the shared-memory update
  e' = e + mean(u_m) - mean(g_m) without touching the window body --
  tests/test_population.py::TestEFStores::test_server_store_semantics.

Stores are registered in :data:`EF_STORES` ("dense" | "int8" | "server");
their measured accuracy cost lives in BENCH_population.json
(benchmarks/bench_population.py) and the trade-off table in
docs/ARCHITECTURE.md §8.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .compressor import LGCCompressor

Array = jax.Array


class EFState(NamedTuple):
    """Flat error memory, one vector per FL device (or per shard)."""
    e: Array  # (D,) float32


def init_ef(d: int) -> EFState:
    return EFState(e=jnp.zeros((d,), jnp.float32))


def ef_compress(state: EFState, delta: Array, compressor: LGCCompressor,
                received: Sequence[bool] | None = None
                ) -> tuple[Array, EFState]:
    """One error-compensated compression step.

    Args:
      state: current error memory e_m.
      delta: net progress  w_m - w_hat_m^{t+1/2}  (i.e. sum of local LR*grads).
      compressor: the LGC_k operator for this round.
      received: optional per-channel delivery mask (channel failure model).

    Returns (g, new_state) where g is the compressed update actually applied
    at the server and new_state carries u - g_sent.  NOTE: when a channel
    drops a layer, that layer's mass stays in the error memory (it was not
    delivered), which is exactly the graceful-degradation property of layered
    coding: the information is retransmitted (with error feedback) later.
    """
    u = state.e + delta
    g_sent = compressor(u, received)          # what the server receives
    g_all = compressor(u, None)               # what the device selected
    # Mass selected but dropped by a channel goes back into the memory too:
    e_new = u - g_sent if received is not None else u - g_all
    del g_all
    return g_sent, EFState(e=e_new)


# ---------------------------------------------------------------------------
# population-scale EF stores (host-resident; cohort gather/scatter interface)
# ---------------------------------------------------------------------------

class DenseEFStore:
    """(N, D) f32 residuals on the host -- the lossless reference store.

    4*N*D bytes: ~3 GB for N=100k at MNIST-LR size (D=7850), which is why
    the int8 and server-side stores below exist.
    """

    name = "dense"

    def __init__(self, n: int, d: int):
        self.n, self.d = n, d
        self._e = np.zeros((n, d), np.float32)

    @property
    def nbytes(self) -> int:
        return self._e.nbytes

    def gather(self, ids: np.ndarray) -> Array:
        """(M, D) f32 residuals of the cohort, device-ready."""
        return jnp.asarray(self._e[ids])

    def scatter(self, ids: np.ndarray, ef: Array) -> None:
        """Write the cohort's post-window residuals back."""
        self._e[ids] = np.asarray(ef, np.float32)


class Int8EFStore:
    """int8 residual codes + one f32 scale per device.

    Symmetric per-device quantization: ``scale = max|e| / 127``,
    ``code = rint(e / scale)``; decode is ``code * scale``.  Per-element
    error <= scale/2.  N*(D + 4) bytes total -- ~26% of dense at D=68,
    dropping toward 25% as D grows.  An all-zero residual row stores
    scale 0 and decodes to exact zeros (no 0/0).
    """

    name = "int8"

    def __init__(self, n: int, d: int):
        self.n, self.d = n, d
        self._codes = np.zeros((n, d), np.int8)
        self._scale = np.zeros((n,), np.float32)

    @property
    def nbytes(self) -> int:
        return self._codes.nbytes + self._scale.nbytes

    def gather(self, ids: np.ndarray) -> Array:
        dec = (self._codes[ids].astype(np.float32)
               * self._scale[ids, None])
        return jnp.asarray(dec)

    def scatter(self, ids: np.ndarray, ef: Array) -> None:
        ef = np.asarray(ef, np.float32)
        scale = np.max(np.abs(ef), axis=1) / 127.0
        safe = np.where(scale > 0, scale, 1.0)
        self._codes[ids] = np.rint(ef / safe[:, None]).astype(np.int8)
        self._scale[ids] = scale


class ServerEFStore:
    """One aggregate (D,) residual held at the server; devices are stateless.

    ``gather`` hands every cohort row the same shared residual, the window
    body computes per-row  e_m' = u_m - g_m  as usual, and ``scatter`` keeps
    the cohort mean -- algebraically  e' = e + mean(delta_m) - mean(g_m),
    the shared-memory error-feedback update, with the window body literally
    unchanged.  4*D bytes regardless of N.
    """

    name = "server"

    def __init__(self, n: int, d: int):
        self.n, self.d = n, d
        self._e = np.zeros((d,), np.float32)

    @property
    def nbytes(self) -> int:
        return self._e.nbytes

    def gather(self, ids: np.ndarray) -> Array:
        return jnp.broadcast_to(jnp.asarray(self._e),
                                (len(ids), self.d))

    def scatter(self, ids: np.ndarray, ef: Array) -> None:
        self._e = np.asarray(ef, np.float32).mean(axis=0)


EF_STORES: dict[str, type] = {
    "dense": DenseEFStore,
    "int8": Int8EFStore,
    "server": ServerEFStore,
}


def make_ef_store(kind: str, n: int, d: int):
    """Instantiate a registered EF store ("dense" | "int8" | "server")."""
    try:
        cls = EF_STORES[kind]
    except KeyError:
        raise ValueError(f"unknown EF store {kind!r}; registered: "
                         f"{sorted(EF_STORES)}") from None
    return cls(n, d)
