"""Theorem 1 / Corollary 1 bound calculator (paper §2.2).

Computes the upper bound on E[f(w_bar^{(T)})] - f* for smooth strongly-convex
losses under LGC with error feedback, given problem constants.  The bound
must be positive, decreasing in T and increasing in H
(tests/test_fl.py::TestTheoremBounds);
``benchmarks.bench_convergence_bound`` tabulates the theory's predictions
against simulator behaviour.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ProblemConstants:
    mu: float          # strong convexity
    l_smooth: float    # smoothness L
    g2: float          # G^2 second-moment bound
    sigma2: float      # sigma^2 gradient variance (max over devices)
    b: int             # mini-batch size
    m: int             # number of devices
    gamma: float       # compressor contraction (k/D for Top_k)
    h: int             # max gap H
    w0_dist2: float    # ||w0 - w*||^2


def theorem1_bound(c: ProblemConstants, t_rounds: int) -> float:
    """Eq. (6)-(7h) evaluated literally."""
    mu, L, H = c.mu, c.l_smooth, float(c.h)
    kappa = L / mu
    gamma = max(c.gamma, 1e-6)
    a = max(4 * H / gamma, 32 * kappa, H) * 1.01 + 1.0
    # Lemma 1 constant C (uniform gamma_m = gamma)
    big_c = 4 * a * gamma * (1 - gamma ** 2) / max(a * gamma - 4 * H, 1e-9)
    c1 = 192 * (4 - 2 * gamma) * (1 + big_c / gamma ** 2)
    c2 = 8 * (4 - 2 * gamma) * (1 + big_c / gamma ** 2)
    bigA = c.sigma2 * c.m / (c.b * c.m ** 2)          # sum sigma_m^2 / (b M^2)
    eta_t = 8.0 / (mu * a)                            # eta^(0), the largest
    bigB = ((1.5 * mu + 3 * L)
            * (12 * big_c * c.g2 * H ** 2 / gamma ** 2
               + c1 * eta_t ** 2 * H ** 4 * c.g2)
            + 24 * (1 + c2 * H ** 2) * L * c.g2 * H ** 2)
    s_total = sum((a + t) ** 2 for t in range(t_rounds))  # S >= T^3/3
    bound = (L * a ** 3 / (4 * s_total) * c.w0_dist2
             + 8 * L * t_rounds * (t_rounds + 2 * a) / (mu ** 2 * s_total) * bigA
             + 128 * L * t_rounds / (mu ** 3 * s_total) * bigB)
    return float(bound)


def corollary1_rate(c: ProblemConstants, t_rounds: int) -> float:
    """Asymptotic rate, Eq. (8): O(G^2H^3 / mu^2 gamma^3 T^3) + O(sigma^2/mu^2 bMT) + ..."""
    mu, H, T = c.mu, float(c.h), float(t_rounds)
    gamma = max(c.gamma, 1e-6)
    return float(
        c.g2 * H ** 3 / (mu ** 2 * gamma ** 3 * T ** 3)
        + c.sigma2 / (mu ** 2 * c.b * c.m * T)
        + H * c.sigma2 / (mu ** 2 * c.b * c.m * gamma * T ** 2)
        + c.g2 * (H ** 2 + H ** 4) / (mu ** 3 * gamma ** 2 * T ** 2))
