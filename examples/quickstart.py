"""Quickstart: LGC federated learning in ~40 lines.

Trains logistic regression on synthetic MNIST across 3 edge devices with
3 channels (3G/4G/5G), layered gradient compression and error feedback,
and compares resource usage against FedAvg.

  PYTHONPATH=src python examples/quickstart.py [--rounds N] [--n-train N]

(The CI docs lane runs this with a reduced budget so the documented entry
point can't rot; defaults match the README walkthrough.)
"""
import argparse

from repro.core import FLConfig, run_baseline
from repro.models.paper_models import make_mnist_task


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=120)
    ap.add_argument("--n-train", type=int, default=3000)
    args = ap.parse_args()
    task = make_mnist_task("lr", m_devices=3, n_train=args.n_train)
    cfg = FLConfig(rounds=args.rounds, eval_every=max(args.rounds // 6, 1))

    print("== LGC (layered compression, 3 channels, fixed H=4) ==")
    lgc = run_baseline(task, cfg, "lgc", h=4)
    for step, loss, acc in zip(lgc.step, lgc.loss, lgc.accuracy):
        print(f"  t={step:4d} loss={loss:.4f} acc={acc:.3f}")

    print("== FedAvg (dense upload) ==")
    avg = run_baseline(task, cfg, "fedavg", h=4)
    print(f"  final loss={avg.loss[-1]:.4f} acc={avg.accuracy[-1]:.3f}")

    print("\n== resource comparison (total across devices) ==")
    rows = [("", "LGC", "FedAvg"),
            ("energy (J)", f"{lgc.energy_j[-1]:.0f}", f"{avg.energy_j[-1]:.0f}"),
            ("money", f"{lgc.money[-1]:.4f}", f"{avg.money[-1]:.4f}"),
            ("uplink (MB)", f"{lgc.uplink_mb[-1]:.2f}", f"{avg.uplink_mb[-1]:.2f}"),
            ("wall time (s)", f"{lgc.time_s[-1]:.1f}", f"{avg.time_s[-1]:.1f}")]
    for r in rows:
        print(f"  {r[0]:>14s}  {r[1]:>10s}  {r[2]:>10s}")
    assert lgc.energy_j[-1] < avg.energy_j[-1]
    print("\nLGC reaches comparable accuracy at a fraction of the resource "
          "cost (paper Fig. 3).")


if __name__ == "__main__":
    main()
