"""Mesh scaling of the sharded LGC engine: device-steps/s vs shard count.

The batched engine vectorizes the device axis on ONE chip; the sharded
engine (``engine="sharded"``) partitions it over the FL axis of a real mesh
so each mesh device simulates M/D edge devices and only the server
aggregation crosses the slow axis.  This bench sweeps the mesh size D for a
fixed fleet (default M=256) and reports two throughputs per row:

* ``device_steps_per_s``        -- end-to-end ``run()`` wall, compile included
  (the number CI users see on a fresh process);
* ``steady_device_steps_per_s`` -- the window program alone: compile once,
  then chain K sync windows back-to-back.  This is the scaling metric: the
  window IS the engine hot loop, and XLA compile time (~10s, independent of
  D) would otherwise swamp the mesh signal at bench budgets.

Each D runs in a fresh subprocess because the host device count
(``--xla_force_host_platform_device_count``) must be fixed before jax
imports.  ``--out`` (and ``benchmarks/run.py``) writes BENCH_sharded.json
for CI artifact upload.

Read the scaling ratio against ``physical_cores`` and ``cpu_util`` in the
JSON: D virtual host devices cannot beat the machine's core count, and this
LR workload is memory-bandwidth-bound on CPU (the minibatch gather moves
~50 MB/round at M=256), so host-mesh ratios near 1.0 on 2-core boxes are
the hardware ceiling, not an engine defect.  The host mesh proves the
mechanism (collectives + sharded state residency) on every push; real
multi-chip meshes, where each shard owns its own memory system, are the
deployment target.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from .common import emit


def _steady_window_rate(sim, eng, m: int, h: int, k_windows: int):
    """Throughput of the compiled window program: chain ``k_windows`` sync
    windows (all devices sync every window, like the end-to-end run with
    fixed controllers) and time everything after the first, compiling, call."""
    import jax
    import jax.numpy as jnp

    sim._decide_devices(range(m), 0)
    k_cap = eng._k_cap()
    sync = jnp.ones((m,), bool)
    ks_mat = eng._ks_mat()
    valid = jnp.ones((h,), bool)

    def win(state, i):
        ts = jnp.arange(i * h, (i + 1) * h, dtype=jnp.int32)
        etas = jnp.asarray([sim._eta(t) for t in range(i * h, (i + 1) * h)],
                           jnp.float32)
        return eng._window(*state, eng.data, eng.n_dev,
                           eng.dev_ids, ts, etas, valid, sync, ks_mat,
                           k_cap=k_cap)

    state = (sim.params, eng.w_hat, eng.anchor, eng.ef, eng.scen_carry)
    out = win(state, 0)
    jax.block_until_ready(out)                     # compile + first window
    state = out[:5]
    t0w, t0c = time.time(), os.times()
    for i in range(1, k_windows + 1):
        out = win(state, i)
        state = out[:5]
    jax.block_until_ready(out)
    wall = time.time() - t0w
    tc = os.times()
    cpu = (tc.user + tc.system) - (t0c.user + t0c.system)
    return m * h * k_windows / wall, cpu / wall


def _worker(n_devices: int, m: int, rounds: int, engine: str,
            k_windows: int) -> None:
    from repro.launch.compat import force_host_device_count
    force_host_device_count(n_devices)     # before first backend init
    import jax
    assert len(jax.devices()) == n_devices, (
        f"worker asked for {n_devices} host devices, backend exposes "
        f"{len(jax.devices())} -- XLA_FLAGS override did not take")
    from repro.core import FLConfig, FixedController, LGCSimulator
    from repro.core.fl_batched import BatchedEngine, ShardedEngine
    from repro.models.paper_models import make_mnist_task

    h = 4
    task = make_mnist_task("lr", m_devices=m, n_train=max(2000, 32 * m))
    cfg = FLConfig(rounds=rounds, eval_every=max(rounds // 2, 1))

    def ctrls():
        return [FixedController(h, [200, 300, 392]) for _ in range(m)]

    # end-to-end: History semantics, compile included
    sim = LGCSimulator(task, cfg, ctrls(), mode="lgc", engine=engine)
    t0 = time.time()
    hist = sim.run()
    wall = time.time() - t0

    # steady state: the window program alone on a fresh engine
    sim2 = LGCSimulator(task, cfg, ctrls(), mode="lgc", engine=engine)
    eng = (ShardedEngine(sim2) if engine == "sharded" else
           BatchedEngine(sim2))
    steady, util = _steady_window_rate(sim2, eng, m, h, k_windows)

    print(json.dumps({
        "engine": engine, "n_devices": n_devices, "m_devices": m,
        "rounds": rounds, "wall_s": round(wall, 3),
        "device_steps_per_s": round(m * rounds / wall, 1),
        "steady_device_steps_per_s": round(steady, 1),
        "cpu_util": round(util, 2),
        "final_loss": round(hist.loss[-1], 4),
    }))


def _spawn(n_devices: int, m: int, rounds: int, engine: str,
           k_windows: int) -> dict:
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_sharded_scaling",
         "--worker", "--devices", str(n_devices), "--m", str(m),
         "--rounds", str(rounds), "--engine", engine,
         "--k-windows", str(k_windows)],
        capture_output=True, text=True, env=os.environ.copy(), timeout=3600)
    if out.returncode != 0:
        raise RuntimeError(f"sharded bench worker (D={n_devices}) failed:\n"
                           + out.stderr[-2000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def run(device_counts=(1, 2, 4, 8), m: int = 256, rounds: int = 40,
        k_windows: int = 25, emit_csv: bool = True) -> dict:
    rows = [_spawn(1, m, rounds, "batched", k_windows)]  # unsharded reference
    for d in device_counts:
        rows.append(_spawn(d, m, rounds, "sharded", k_windows))
    if emit_csv:
        for row in rows:
            emit(f"sharded_scaling_{row['engine']}_d{row['n_devices']}_m{m}",
                 row["wall_s"] * 1e6 / rounds,
                 f"steady_device_steps_per_s="
                 f"{row['steady_device_steps_per_s']};"
                 f"cpu_util={row['cpu_util']};"
                 f"final_loss={row['final_loss']}")
    sharded = {r["n_devices"]: r["steady_device_steps_per_s"] for r in rows
               if r["engine"] == "sharded"}
    d_max = max(sharded)
    scaling = round(sharded[d_max] / sharded[1], 2) if 1 in sharded else None
    if emit_csv and scaling is not None:
        emit(f"sharded_scaling_ratio_1_to_{d_max}_m{m}", 0.0,
             f"scaling={scaling}x")
    return {"benchmark": "sharded_scaling", "task": "lr-mnist",
            "m_devices": m, "rounds": rounds, "k_windows": k_windows,
            "physical_cores": os.cpu_count(), "rows": rows,
            "device_steps_scaling_1_to_max": scaling}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--device-counts", default="1,2,4,8")
    ap.add_argument("--m", type=int, default=256)
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--k-windows", type=int, default=25)
    ap.add_argument("--engine", default="sharded")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.worker:
        _worker(args.devices, args.m, args.rounds, args.engine,
                args.k_windows)
        return
    res = run(device_counts=tuple(int(x) for x in
                                  args.device_counts.split(",")),
              m=args.m, rounds=args.rounds, k_windows=args.k_windows)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
